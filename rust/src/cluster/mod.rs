//! Sharded multi-tenant cluster simulation over the kernel-optimization
//! service.
//!
//! `service::KernelService` prices one node: one result cache, one
//! simulated GPU fleet. The ROADMAP's target — serving millions of users —
//! is a *cluster* of such nodes, and the questions that matter at that
//! scale are cluster questions: how evenly do fingerprints shard, what does
//! a node failure cost, which tenant starves under overload, and when is it
//! worth fetching a warm-start seed from another node's shard. This module
//! answers them with the same discrete-event discipline as the single-node
//! layer:
//!
//! - [`router`] — rendezvous (highest-random-weight) hashing routes each
//!   fingerprint to one alive node; a node's death moves only its own keys.
//! - Each simulated node owns its **own** `ResultCache` shard and
//!   `FleetSim` worker slice — there is no shared cache, so a request
//!   hitting the "wrong" node's shard is impossible by construction.
//! - **Tenancy.** Every trace request carries a tenant index. Under
//!   overload (a node's flight backlog at `queue_depth`), weighted
//!   fair-share quotas meter who may open *new* flights: tenant `i` may
//!   hold at most `queue_depth * weight_i / total_weight` backlog slots.
//!   Quota sheds are counted per tenant — the old global batch-shed is no
//!   longer the only admission knob (it still applies first).
//! - **Failure/rebalance.** A configured node drops mid-replay: its cache
//!   shard is lost (entries counted), accepted work drains gracefully, and
//!   subsequent requests for its keys rehash to surviving nodes where they
//!   re-miss — the re-run flights and their API dollars are accounted in
//!   [`RebalanceReport`].
//! - **Cross-node warm starts.** A miss on node A may seed from the best
//!   hit-adjacent entry owned by node B, paying a configurable transfer
//!   latency on top of the run's service time.
//!
//! # Determinism and causality
//!
//! The replay drives every node fleet through one *global* event loop:
//! starts and completions fire in cluster-wide timestamp order (completions
//! before starts at ties, then node index), interleaved with arrivals. A
//! flight starting on any node therefore observes exactly the cache
//! entries — its own shard's and other shards' warm-start donors — whose
//! producing flights completed by its start instant, never a result still
//! being computed. Everything reported is simulated-time or request-count
//! arithmetic accumulated in that event order; OS `threads` and the
//! `window` speculation batch size only change how fast the host crunches
//! workflow runs. A [`ClusterReport`] is bit-identical across thread
//! counts, and a 1-node single-tenant cluster replay is bit-identical to
//! [`KernelService::replay`]'s `ServiceReport` — both invariants are
//! asserted by `tests/integration_cluster.rs`, and the per-flight
//! accounting itself is one shared helper
//! (`service::settle_flight_completion`), not parallel code.
//!
//! [`KernelService::replay`]: crate::service::KernelService::replay

pub mod router;

use std::collections::{BTreeMap, BTreeSet};

use crate::service::cache::{CacheEntry, ResultCache};
use crate::service::fingerprint::Fingerprint;
use crate::service::pool::{FleetHooks, FleetSim, SimCompletion, SimFlight};
use crate::service::queue::Priority;
use crate::service::traffic::TrafficRequest;
use crate::service::{
    per_priority_report, settle_flight_completion, speculate_window, PendingRun, ReplayStats,
    RunMemo, ServiceConfig, ServiceReport,
};
use crate::tasks::TaskSpec;
use crate::util::stats::percentile;
use crate::workflow::{run_task, CorrectnessOracle};

pub use router::Router;

/// One tenant of the cluster: a name for reporting and a fair-share weight.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    /// Relative share of each node's flight backlog this tenant may hold
    /// under overload (see [`fair_share_quotas`]). Non-positive weights get
    /// the minimum quota of one slot.
    pub weight: f64,
}

impl TenantSpec {
    pub fn new(name: impl Into<String>, weight: f64) -> TenantSpec {
        TenantSpec { name: name.into(), weight }
    }
}

/// Cluster deployment parameters. `service` holds the *per-node* knobs:
/// `capacity` is each shard's entry budget, `sim_workers` each node's
/// simulated GPU slice, `queue_depth` each node's admission bound;
/// `window` and `threads` stay cluster-global (both are host-speed knobs
/// with no effect on reported numbers).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub service: ServiceConfig,
    /// Simulated nodes (clamped to at least 1).
    pub nodes: usize,
    /// The tenant population. `TrafficRequest::tenant` indexes this list
    /// (out-of-range indices clamp to the last tenant).
    pub tenants: Vec<TenantSpec>,
    /// Enforce weighted fair-share quotas under overload. Off by default so
    /// a 1-node, 1-tenant cluster reproduces the single-node service's
    /// admission behaviour exactly (only batch work is shed at the bound).
    pub tenant_quotas: bool,
    /// Simulated seconds to fetch a warm-start seed kernel from another
    /// node's shard, added to the run's service time.
    pub transfer_latency_s: f64,
    /// Fail node `.0` the first time simulated time reaches `.1` seconds
    /// (at an arrival, or during the final drain if the instant falls after
    /// the last arrival): its cache shard is lost and later requests for
    /// its keys rehash.
    pub fail_node_at: Option<(usize, f64)>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            service: ServiceConfig::default(),
            nodes: 4,
            tenants: vec![TenantSpec::new("default", 1.0)],
            tenant_quotas: false,
            transfer_latency_s: 30.0,
            fail_node_at: None,
        }
    }
}

/// Per-node backlog quota for each tenant: its weight-share of
/// `queue_depth`, floored, but never below one slot (every tenant can make
/// progress). An unbounded queue disables quotas entirely.
pub fn fair_share_quotas(queue_depth: usize, tenants: &[TenantSpec]) -> Vec<usize> {
    let total: f64 = tenants.iter().map(|t| t.weight.max(0.0)).sum();
    tenants
        .iter()
        .map(|t| {
            if queue_depth == usize::MAX || total <= 0.0 {
                usize::MAX
            } else {
                let share = queue_depth as f64 * t.weight.max(0.0) / total;
                (share.floor() as usize).max(1)
            }
        })
        .collect()
}

/// One node's serving-state slice, with its cache-effectiveness and
/// utilization aggregates for the replay.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeReport {
    pub node: usize,
    /// False once the failure event killed this node.
    pub alive: bool,
    /// Requests routed to this node (hits + joins + flights + sheds).
    pub requests: usize,
    pub cache_hits: u64,
    pub shared: u64,
    pub flights_run: usize,
    pub rejected: u64,
    pub evictions: u64,
    pub hit_rate: f64,
    /// Busy time / (node workers × node makespan).
    pub utilization: f64,
    pub peak_queue_depth: usize,
    /// Entries resident in this node's shard after the replay.
    pub cache_entries: usize,
}

/// One tenant's outcome: traffic volume, shed counts, and latency/SLO
/// aggregates (each served request scored against its own priority class's
/// target).
#[derive(Clone, Debug, PartialEq)]
pub struct TenantReport {
    pub tenant: String,
    pub weight: f64,
    pub requests: usize,
    /// Requests that got an answer (requests − rejected).
    pub served: usize,
    /// All sheds of this tenant's traffic (batch overload + quota).
    pub rejected: u64,
    /// The subset of `rejected` shed specifically by this tenant exceeding
    /// its fair-share quota.
    pub quota_shed: u64,
    pub p50_latency_s: f64,
    pub p95_latency_s: f64,
    pub p99_latency_s: f64,
    /// Fraction of served requests within their priority class's SLO
    /// target (1.0 when nothing was served — a vacuous SLO holds).
    pub slo_attainment: f64,
}

/// What the configured node failure cost.
#[derive(Clone, Debug, PartialEq)]
pub struct RebalanceReport {
    pub failed_node: usize,
    pub failed_at_s: f64,
    /// Cache entries the dead node's shard held — all lost.
    pub cache_entries_lost: usize,
    /// Post-failure requests whose rendezvous owner *would have been* the
    /// dead node — the traffic that rehashed to survivors.
    pub rehashed_requests: usize,
    /// Lost keys that had to re-run a full workflow on a surviving node.
    pub remissed_flights: usize,
    /// API dollars those re-runs spent — work the cluster had already paid
    /// for once.
    pub remiss_api_usd: f64,
}

/// Everything a cluster replay reports. `overall` is shaped exactly like
/// the single-node report (and *is* that report, bit for bit, for a 1-node
/// single-tenant cluster); the per-node / per-tenant / rebalance views are
/// what the sharded deployment adds.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterReport {
    pub overall: ServiceReport,
    pub nodes: usize,
    pub per_node: Vec<NodeReport>,
    pub per_tenant: Vec<TenantReport>,
    /// Executed misses that warm-started from an entry owned by a
    /// *different* node (each paid `transfer_latency_s`).
    pub cross_node_warm: usize,
    /// Total quota-exceeded sheds across tenants.
    pub quota_shed: u64,
    /// Present when `fail_node_at` fired during the replay.
    pub rebalance: Option<RebalanceReport>,
}

/// Best warm-start candidate across every *alive* shard, with its owning
/// node (a dead node's entries are unreachable, not warm-start donors).
/// Ties break on (speedup, fingerprint, node) so the scan order can never
/// change the pick.
fn warm_candidate_across<'c>(
    caches: &'c [ResultCache],
    c: &ServiceConfig,
    task_id: &str,
    gpu_key: &str,
    alive: &[bool],
) -> Option<(usize, &'c CacheEntry)> {
    let mut best: Option<(usize, &CacheEntry)> = None;
    for (node, cache) in caches.iter().enumerate() {
        if !alive.get(node).copied().unwrap_or(false) {
            continue;
        }
        let cand = cache.warm_candidate(
            task_id,
            gpu_key,
            c.strategy.name(),
            c.coder.name,
            c.judge.name,
        );
        if let Some(e) = cand {
            let better = match best {
                None => true,
                Some((bn, b)) => e
                    .best_speedup
                    .total_cmp(&b.best_speedup)
                    .then_with(|| e.fingerprint.cmp(&b.fingerprint))
                    .then_with(|| node.cmp(&bn))
                    .is_gt(),
            };
            if better {
                best = Some((node, e));
            }
        }
    }
    best
}

/// Per-node admission/serving counters for one replay.
struct NodeCounters {
    requests: usize,
    hits: u64,
    shared: u64,
    flights_run: usize,
    rejected: u64,
    peak_depth: usize,
    /// Flights opened but not yet started, per tenant — the fair-share
    /// quota meter (the slot is released when the flight starts on a
    /// worker).
    backlog_by_tenant: Vec<usize>,
    /// This node's cache eviction counter at replay start (delta basis).
    evictions0: u64,
    /// Evictions accumulated before the cache shard was dropped by the
    /// failure event (the replacement cache restarts its counter).
    evictions_carry: u64,
}

/// The cluster replay context. Implements [`FleetHooks`] for whichever node
/// fleet is currently stepping (`node` is set by the global event loop):
/// start events pick the warm seed across alive shards at event-time state,
/// completion events apply side effects via the accounting helper shared
/// with the single-node replay.
struct ClusterHooks<'a> {
    config: &'a ClusterConfig,
    trace: &'a [TrafficRequest],
    tasks: &'a [TaskSpec],
    oracle: &'a dyn CorrectnessOracle,
    caches: &'a mut Vec<ResultCache>,
    cold_cost: &'a mut BTreeMap<Fingerprint, f64>,
    stats: ReplayStats,
    memo: RunMemo,
    pending: BTreeMap<u64, PendingRun>,
    /// Causality audit: the completion instant of each fingerprint's
    /// producing flight *this replay* (absent = resident before it started).
    visible_at: BTreeMap<Fingerprint, f64>,
    per_node: Vec<NodeCounters>,
    alive: Vec<bool>,
    /// The node whose fleet is currently stepping.
    node: usize,
    cross_node_warm: usize,
    rebalance: Option<RebalanceReport>,
    lost_keys: BTreeSet<Fingerprint>,
}

impl FleetHooks for ClusterHooks<'_> {
    fn on_start(&mut self, flight: &SimFlight, start_s: f64) -> f64 {
        let req = &self.trace[flight.leader_seq as usize];
        let task = &self.tasks[req.task_index];
        let c = &self.config.service;
        // The flight leaves the backlog: release its tenant's quota slot.
        let nc = &mut self.per_node[self.node];
        nc.backlog_by_tenant[flight.tenant] =
            nc.backlog_by_tenant[flight.tenant].saturating_sub(1);
        let base = c.base_workflow(req.gpu);
        let (wf, cross) = match warm_candidate_across(
            self.caches,
            c,
            &task.id(),
            req.gpu.key,
            &self.alive,
        ) {
            Some((owner, entry)) => {
                // The causality contract: a warm seed's producing flight —
                // on any node — completed no later than this start.
                if let Some(done) = self.visible_at.get(&entry.fingerprint) {
                    debug_assert!(
                        *done <= start_s,
                        "warm seed {} completes at {done} > consumer start {start_s}",
                        entry.fingerprint,
                    );
                }
                (c.warm_start_from(base, entry), owner != self.node)
            }
            None => (base, false),
        };
        if cross {
            self.cross_node_warm += 1;
        }
        let result = match self.memo.take(flight.fingerprint, &wf.warm_start) {
            Some(r) => r,
            // Speculation missed: run inline with the true event-time
            // workflow.
            None => run_task(&wf, task, self.oracle),
        };
        // A cross-node seed is fetched before the run starts: the transfer
        // rides on the flight's service time.
        let service_s = result.ledger.wall_s
            + if cross { self.config.transfer_latency_s } else { 0.0 };
        self.pending.insert(
            flight.leader_seq,
            PendingRun { result, warm: wf.warm_start.is_some() },
        );
        service_s
    }

    fn on_complete(&mut self, flight: &SimFlight, done: SimCompletion) {
        let run = self
            .pending
            .remove(&flight.leader_seq)
            .expect("a completion follows its start");
        let req = &self.trace[flight.leader_seq as usize];
        let task = &self.tasks[req.task_index];
        let entry = settle_flight_completion(
            &self.config.service,
            &mut self.stats,
            self.cold_cost,
            task,
            req.gpu.key,
            flight,
            done,
            run.warm,
            &run.result,
        );
        let nc = &mut self.per_node[self.node];
        nc.flights_run += 1;
        nc.shared += (flight.members.len() - 1) as u64;
        if let Some(rb) = self.rebalance.as_mut() {
            // A lost key's first re-run is the failure's re-miss cost: work
            // the dead shard had already paid for.
            if self.lost_keys.remove(&flight.fingerprint) {
                rb.remissed_flights += 1;
                rb.remiss_api_usd += run.result.ledger.api_usd;
            }
        }
        // A dead node's draining flights still answer their members, but
        // their results must not repopulate the unreachable shard (the
        // router will never send a request there again).
        if self.alive[self.node] {
            if let Some(e) = entry {
                self.visible_at.insert(e.fingerprint, done.completion_s);
                self.caches[self.node].insert(e);
            }
        }
    }
}

/// Apply the configured node failure if simulated time has reached it: fire
/// everything due strictly by `ftime` first (the shard is alive for those
/// events), then drop the shard and record the loss. Consulted at every
/// arrival *and* before the final drain, so the failure lands at its own
/// instant even when it falls after the last arrival.
fn apply_failure_if_due(
    config: &ClusterConfig,
    nodes: usize,
    now: f64,
    fleets: &mut [FleetSim],
    hooks: &mut ClusterHooks,
) {
    let Some((fnode, ftime)) = config.fail_node_at else { return };
    if fnode >= nodes || !hooks.alive[fnode] || now < ftime {
        return;
    }
    advance_fleets(fleets, ftime, hooks);
    hooks.alive[fnode] = false;
    let lost: Vec<Fingerprint> = hooks.caches[fnode]
        .entries_coldest_first()
        .map(|e| e.fingerprint)
        .collect();
    hooks.lost_keys.extend(lost);
    let carry = hooks.caches[fnode].stats.evictions;
    hooks.caches[fnode] = ResultCache::new(config.service.capacity);
    let nc = &mut hooks.per_node[fnode];
    nc.evictions_carry = carry - nc.evictions0;
    nc.evictions0 = 0;
    hooks.rebalance = Some(RebalanceReport {
        failed_node: fnode,
        failed_at_s: ftime,
        cache_entries_lost: hooks.lost_keys.len(),
        rehashed_requests: 0,
        remissed_flights: 0,
        remiss_api_usd: 0.0,
    });
}

/// Fire every start/completion due by `now` across all node fleets, in
/// global timestamp order — completions before starts at equal instants,
/// then node index — so a flight starting on node A at instant `t` observes
/// exactly the side effects of every flight, on any node, completed by `t`.
fn advance_fleets(fleets: &mut [FleetSim], now: f64, hooks: &mut ClusterHooks) {
    loop {
        let mut best: Option<(f64, u8, usize)> = None;
        for (ni, fleet) in fleets.iter().enumerate() {
            if let Some((t, is_completion)) = fleet.next_event() {
                let key = (t, u8::from(!is_completion), ni);
                let earlier = match best {
                    None => true,
                    Some(b) => key < b,
                };
                if earlier {
                    best = Some(key);
                }
            }
        }
        match best {
            Some((t, _, ni)) if t <= now => {
                hooks.node = ni;
                let fired = fleets[ni].step(now, &mut *hooks);
                debug_assert!(fired, "the peeked event fires");
            }
            _ => break,
        }
    }
}

/// The long-lived cluster: a router plus N cache shards and the
/// cluster-wide cold-cost registry (counterfactual pricing is a property of
/// fingerprints, not of which shard served them).
pub struct ClusterService {
    pub config: ClusterConfig,
    router: Router,
    caches: Vec<ResultCache>,
    cold_cost: BTreeMap<Fingerprint, f64>,
}

impl ClusterService {
    pub fn new(mut config: ClusterConfig) -> ClusterService {
        config.nodes = config.nodes.max(1);
        if config.tenants.is_empty() {
            config.tenants.push(TenantSpec::new("default", 1.0));
        }
        let caches = (0..config.nodes)
            .map(|_| ResultCache::new(config.service.capacity))
            .collect();
        let router = Router::new(config.nodes);
        ClusterService { config, router, caches, cold_cost: BTreeMap::new() }
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Node `n`'s cache shard (introspection/tests).
    pub fn cache(&self, n: usize) -> &ResultCache {
        &self.caches[n]
    }

    /// Replay a traffic trace through the cluster. One event-driven loop
    /// mirrors [`crate::service::KernelService::replay`] per node —
    /// per-arrival admission, single-flight joins, completion-instant side
    /// effects — plus routing, tenancy, failure, and cross-node warm
    /// starts. Deterministic per (config, trace); OS `threads` and the
    /// `window` batch size change wall-clock only.
    pub fn replay(
        &mut self,
        trace: &[TrafficRequest],
        tasks: &[TaskSpec],
        oracle: &dyn CorrectnessOracle,
    ) -> ClusterReport {
        let nodes = self.config.nodes;
        let n_tenants = self.config.tenants.len();
        let window = self.config.service.window.max(1);
        let sim_workers = self.config.service.sim_workers.max(1);
        let queue_depth = self.config.service.queue_depth;
        let hit_latency_s = self.config.service.hit_latency_s;
        let threads = self.config.service.threads;
        let quotas_on = self.config.tenant_quotas;
        let quotas = fair_share_quotas(queue_depth, &self.config.tenants);
        debug_assert!(
            trace.windows(2).all(|p| p[0].arrival_s <= p[1].arrival_s),
            "trace must be sorted by arrival time"
        );

        // Shard eviction counters at replay start (delta basis), snapshotted
        // before the caches are mutably loaned to the hooks.
        let evictions0: Vec<u64> = self.caches.iter().map(|c| c.stats.evictions).collect();
        let config = &self.config;
        let router = &self.router;
        let caches = &mut self.caches;
        let cold_cost = &mut self.cold_cost;

        let mut fleets: Vec<FleetSim> =
            (0..nodes).map(|_| FleetSim::new(sim_workers)).collect();
        let mut rejected = 0u64;
        let mut rejected_by_class = [0u64; 3];
        let mut tenant_requests = vec![0usize; n_tenants];
        let mut tenant_rejected = vec![0u64; n_tenants];
        let mut tenant_quota_shed = vec![0u64; n_tenants];

        let mut hooks = ClusterHooks {
            config,
            trace,
            tasks,
            oracle,
            caches,
            cold_cost,
            stats: ReplayStats::new(trace.len()),
            memo: RunMemo::default(),
            pending: BTreeMap::new(),
            visible_at: BTreeMap::new(),
            per_node: (0..nodes)
                .map(|i| NodeCounters {
                    requests: 0,
                    hits: 0,
                    shared: 0,
                    flights_run: 0,
                    rejected: 0,
                    peak_depth: 0,
                    backlog_by_tenant: vec![0; n_tenants],
                    evictions0: evictions0[i],
                    evictions_carry: 0,
                })
                .collect(),
            alive: vec![true; nodes],
            node: 0,
            cross_node_warm: 0,
            rebalance: None,
            lost_keys: BTreeSet::new(),
        };

        for (w0, win) in trace.chunks(window).enumerate().map(|(i, w)| (i * window, w)) {
            // ---- speculation: batch-run predicted misses on OS threads ---
            {
                let caches: &[ResultCache] = hooks.caches;
                let alive = &hooks.alive;
                let fleets = &fleets;
                let c = &config.service;
                // Sweep speculations that never became flights (their
                // request hit, joined, or was shed) so the memo stays
                // bounded by the backlog, not the trace.
                hooks.memo.retain(|fp| {
                    fleets.iter().any(|f| f.is_waiting(fp) || f.is_running(fp))
                });
                speculate_window(&mut hooks.memo, threads, tasks, oracle, win, c, |fp, req| {
                    let ni = router.route(fp, alive)?;
                    if caches[ni].peek(fp).is_some()
                        || fleets[ni].is_waiting(fp)
                        || fleets[ni].is_running(fp)
                    {
                        return None;
                    }
                    // A batch request arriving into a full backlog will be
                    // shed — don't burn a speculative run on it.
                    if req.priority == Priority::Batch && fleets[ni].depth() >= queue_depth {
                        return None;
                    }
                    let base = c.base_workflow(req.gpu);
                    Some(
                        match warm_candidate_across(
                            caches,
                            c,
                            &tasks[req.task_index].id(),
                            req.gpu.key,
                            alive,
                        ) {
                            Some((_, entry)) => c.warm_start_from(base, entry),
                            None => base,
                        },
                    )
                });
            }

            // ---- admission: event-driven, one arrival at a time ----------
            for (off, req) in win.iter().enumerate() {
                let seq = (w0 + off) as u64;
                let now = req.arrival_s;
                let t = req.tenant.min(n_tenants - 1);
                // The failure event: drop the node's shard at its own
                // instant, remember its keys, keep serving its accepted
                // work (graceful drain). Starts between the failure and
                // this arrival already see the node dead.
                apply_failure_if_due(config, nodes, now, &mut fleets, &mut hooks);
                // Fire every start/completion due by `now`, cluster-wide,
                // so this arrival observes exactly the flights completed by
                // its own instant.
                advance_fleets(&mut fleets, now, &mut hooks);
                let fp = config.service.fingerprint_of(&tasks[req.task_index], req.gpu);
                if let Some(rb) = hooks.rebalance.as_mut() {
                    if router.route_any(fp) == rb.failed_node {
                        rb.rehashed_requests += 1;
                    }
                }
                // Every arrival is this tenant's traffic, even one the
                // cluster cannot route (served + rejected == requests must
                // hold per tenant).
                tenant_requests[t] += 1;
                let ni = match router.route(fp, &hooks.alive) {
                    Some(n) => n,
                    None => {
                        // Every node is dead: shed unconditionally.
                        rejected += 1;
                        rejected_by_class[req.priority as usize] += 1;
                        tenant_rejected[t] += 1;
                        continue;
                    }
                };
                hooks.per_node[ni].requests += 1;
                let fleet = &mut fleets[ni];
                // Single-flight joins first: identical work waiting or on a
                // worker is shared, not redone. Joiners settle with the
                // flight at its completion.
                if fleet.join_waiting(fp, seq, now, req.priority)
                    || fleet.join_running(fp, seq, now)
                {
                    // joined
                } else if let Some(entry) = hooks.caches[ni].get(fp) {
                    if let Some(done) = hooks.visible_at.get(&fp) {
                        debug_assert!(
                            *done <= now,
                            "cache hit on {fp}: producing flight completes at {done} > arrival {now}",
                        );
                    }
                    hooks.stats.latencies[seq as usize] = Some(hit_latency_s);
                    hooks.stats.api_cold += entry.cold_api_usd;
                    hooks.per_node[ni].hits += 1;
                } else {
                    // Miss: admission control. The global batch-shed
                    // applies first (as on a single node), then the
                    // tenant's fair-share quota — both only against
                    // requests opening a *new* flight; joins are always
                    // free.
                    let over = fleet.depth() >= queue_depth;
                    if over && req.priority == Priority::Batch {
                        hooks.per_node[ni].rejected += 1;
                        rejected += 1;
                        rejected_by_class[req.priority as usize] += 1;
                        tenant_rejected[t] += 1;
                    } else if over
                        && quotas_on
                        && hooks.per_node[ni].backlog_by_tenant[t] >= quotas[t]
                    {
                        hooks.per_node[ni].rejected += 1;
                        rejected += 1;
                        rejected_by_class[req.priority as usize] += 1;
                        tenant_rejected[t] += 1;
                        tenant_quota_shed[t] += 1;
                    } else {
                        fleet.submit(SimFlight {
                            fingerprint: fp,
                            priority: req.priority,
                            leader_seq: seq,
                            tenant: t,
                            arrival_s: now,
                            members: vec![(seq, now)],
                        });
                        hooks.per_node[ni].backlog_by_tenant[t] += 1;
                    }
                }
                // Every admission decision samples this node's backlog —
                // hits, joins, and sheds included.
                let nc = &mut hooks.per_node[ni];
                nc.peak_depth = nc.peak_depth.max(fleet.depth());
            }
        }
        // Drain: serve everything still waiting or running at end of trace.
        // A failure instant past the last arrival still fires here — the
        // drain advances simulated time through it.
        apply_failure_if_due(config, nodes, f64::INFINITY, &mut fleets, &mut hooks);
        advance_fleets(&mut fleets, f64::INFINITY, &mut hooks);
        debug_assert!(hooks.pending.is_empty(), "every started flight completed");

        let ReplayStats {
            latencies,
            api_spent,
            api_cold,
            flights_run,
            warm_started,
            warm_correct,
            shared,
            cold_rounds,
            warm_rounds,
        } = hooks.stats;
        let served: Vec<f64> = latencies.iter().filter_map(|l| *l).collect();
        debug_assert_eq!(
            served.len() + rejected as usize,
            trace.len(),
            "every request is served or rejected"
        );
        let slo = config.service.slo;
        let per_priority = per_priority_report(trace, &latencies, &slo, &rejected_by_class);

        let hits: u64 = hooks.per_node.iter().map(|s| s.hits).sum();
        let evictions: u64 = hooks
            .per_node
            .iter()
            .enumerate()
            .map(|(i, s)| s.evictions_carry + hooks.caches[i].stats.evictions - s.evictions0)
            .sum();
        let busy_s: f64 = fleets.iter().map(|f| f.busy_s()).sum();
        let makespan = fleets.iter().map(|f| f.makespan_s()).fold(0.0f64, f64::max);
        let wait_s: f64 = fleets.iter().map(|f| f.total_queue_wait_s()).sum();
        let served_flights: usize = fleets.iter().map(|f| f.flights_served()).sum();
        let total_workers = nodes * sim_workers;
        let gpu_hours = busy_s / 3600.0;

        let per_node: Vec<NodeReport> = hooks
            .per_node
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let node_makespan = fleets[i].makespan_s();
                NodeReport {
                    node: i,
                    alive: hooks.alive[i],
                    requests: s.requests,
                    cache_hits: s.hits,
                    shared: s.shared,
                    flights_run: s.flights_run,
                    rejected: s.rejected,
                    evictions: s.evictions_carry + hooks.caches[i].stats.evictions
                        - s.evictions0,
                    hit_rate: if s.requests == 0 {
                        0.0
                    } else {
                        (s.hits + s.shared) as f64 / s.requests as f64
                    },
                    utilization: if node_makespan > 0.0 {
                        fleets[i].busy_s() / (sim_workers as f64 * node_makespan)
                    } else {
                        0.0
                    },
                    peak_queue_depth: s.peak_depth,
                    cache_entries: hooks.caches[i].len(),
                }
            })
            .collect();

        let per_tenant: Vec<TenantReport> = config
            .tenants
            .iter()
            .enumerate()
            .map(|(t, spec)| {
                let lat: Vec<f64> = trace
                    .iter()
                    .zip(&latencies)
                    .filter(|(r, _)| r.tenant.min(n_tenants - 1) == t)
                    .filter_map(|(_, l)| *l)
                    .collect();
                let within = trace
                    .iter()
                    .zip(&latencies)
                    .filter(|(r, _)| r.tenant.min(n_tenants - 1) == t)
                    .filter_map(|(r, l)| l.map(|v| (r.priority, v)))
                    .filter(|(p, v)| *v <= slo.target_s(*p))
                    .count();
                TenantReport {
                    tenant: spec.name.clone(),
                    weight: spec.weight,
                    requests: tenant_requests[t],
                    served: lat.len(),
                    rejected: tenant_rejected[t],
                    quota_shed: tenant_quota_shed[t],
                    p50_latency_s: percentile(&lat, 50.0),
                    p95_latency_s: percentile(&lat, 95.0),
                    p99_latency_s: percentile(&lat, 99.0),
                    slo_attainment: if lat.is_empty() {
                        1.0
                    } else {
                        within as f64 / lat.len() as f64
                    },
                }
            })
            .collect();

        let overall = ServiceReport {
            requests: trace.len(),
            flights_run,
            cache_hits: hits,
            shared,
            evictions,
            rejected,
            warm_started,
            warm_correct,
            hit_rate: if trace.is_empty() {
                0.0
            } else {
                (hits + shared) as f64 / trace.len() as f64
            },
            p50_latency_s: percentile(&served, 50.0),
            p95_latency_s: percentile(&served, 95.0),
            p99_latency_s: percentile(&served, 99.0),
            mean_latency_s: crate::util::stats::mean(&served),
            mean_queue_wait_s: if served_flights == 0 {
                0.0
            } else {
                wait_s / served_flights as f64
            },
            peak_queue_depth: hooks.per_node.iter().map(|s| s.peak_depth).max().unwrap_or(0),
            utilization: if makespan > 0.0 {
                busy_s / (total_workers as f64 * makespan)
            } else {
                0.0
            },
            per_priority,
            api_usd_spent: api_spent,
            api_usd_saved: api_cold - api_spent,
            api_usd_cold: api_cold,
            mean_rounds_to_best_cold: crate::util::stats::mean(&cold_rounds),
            mean_rounds_to_best_warm: crate::util::stats::mean(&warm_rounds),
            gpu_hours,
            requests_per_gpu_hour: if gpu_hours > 0.0 {
                trace.len() as f64 / gpu_hours
            } else {
                0.0
            },
        };

        ClusterReport {
            overall,
            nodes,
            per_node,
            per_tenant,
            cross_node_warm: hooks.cross_node_warm,
            quota_shed: tenant_quota_shed.iter().sum(),
            rebalance: hooks.rebalance,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu;
    use crate::service::traffic::{generate, TrafficConfig};
    use crate::tasks;
    use crate::workflow::NoOracle;

    #[test]
    fn fair_shares_follow_weights_with_a_floor() {
        let tenants = vec![TenantSpec::new("a", 3.0), TenantSpec::new("b", 1.0)];
        assert_eq!(fair_share_quotas(8, &tenants), vec![6, 2]);
        // Tiny weights still get one slot; unbounded depth disables quotas.
        let skew = vec![TenantSpec::new("big", 100.0), TenantSpec::new("tiny", 0.0001)];
        assert_eq!(fair_share_quotas(4, &skew), vec![3, 1]);
        assert_eq!(
            fair_share_quotas(usize::MAX, &tenants),
            vec![usize::MAX, usize::MAX]
        );
        // Degenerate weights fall back to "no quota" rather than panicking.
        let zeros = vec![TenantSpec::new("z", 0.0)];
        assert_eq!(fair_share_quotas(8, &zeros), vec![usize::MAX]);
    }

    #[test]
    fn requests_partition_across_nodes_and_tenants() {
        let suite = tasks::kernelbench();
        let trace = generate(
            suite.len(),
            &TrafficConfig {
                requests: 300,
                tenant_mix: vec![("a".to_string(), 1.0), ("b".to_string(), 1.0)],
                ..TrafficConfig::default()
            },
        );
        let mut cluster = ClusterService::new(ClusterConfig {
            nodes: 3,
            tenants: vec![TenantSpec::new("a", 1.0), TenantSpec::new("b", 1.0)],
            service: ServiceConfig {
                threads: 2,
                window: 16,
                ..ServiceConfig::default()
            },
            ..ClusterConfig::default()
        });
        let r = cluster.replay(&trace, &suite, &NoOracle);
        assert_eq!(r.nodes, 3);
        assert_eq!(r.per_node.len(), 3);
        assert_eq!(r.per_tenant.len(), 2);
        assert_eq!(
            r.per_node.iter().map(|n| n.requests).sum::<usize>(),
            r.overall.requests,
            "routing partitions the trace across shards"
        );
        assert!(
            r.per_node.iter().filter(|n| n.requests > 0).count() >= 2,
            "rendezvous hashing spreads this trace over multiple nodes"
        );
        assert_eq!(
            r.per_tenant.iter().map(|t| t.requests).sum::<usize>(),
            r.overall.requests
        );
        for t in &r.per_tenant {
            assert_eq!(t.served as u64 + t.rejected, t.requests as u64);
            assert!((0.0..=1.0).contains(&t.slo_attainment));
        }
        assert_eq!(
            r.overall.cache_hits + r.overall.shared + r.overall.flights_run as u64
                + r.overall.rejected,
            r.overall.requests as u64,
            "every request is a hit, a follower, a flight, or shed"
        );
        assert!(r.rebalance.is_none());
        assert_eq!(r.quota_shed, 0, "quotas are off by default");
    }

    #[test]
    fn failure_after_the_last_arrival_fires_during_the_drain() {
        // The failure instant falls past every arrival: the final drain
        // still advances simulated time through it, so the shard drop (and
        // its entry-loss accounting) is reported instead of silently
        // skipped.
        let suite = tasks::kernelbench();
        let probe_cfg = ServiceConfig { threads: 1, ..ServiceConfig::default() };
        let anchor = (0..suite.len())
            .find(|i| {
                let wf = probe_cfg.base_workflow(gpu::by_key("rtx6000").unwrap());
                let r = run_task(&wf, &suite[*i], &NoOracle);
                r.correct && r.best_speedup > 0.0 && r.best_config.is_some()
            })
            .expect("some task solves cold on rtx6000");
        let trace = vec![TrafficRequest {
            task_index: anchor,
            gpu: gpu::by_key("rtx6000").unwrap(),
            priority: Priority::Standard,
            tenant: 0,
            arrival_s: 0.0,
        }];
        let mut cluster = ClusterService::new(ClusterConfig {
            nodes: 1,
            // Long after the lone flight completes (~26 simulated minutes).
            fail_node_at: Some((0, 100_000.0)),
            service: probe_cfg,
            ..ClusterConfig::default()
        });
        let r = cluster.replay(&trace, &suite, &NoOracle);
        assert_eq!(r.overall.flights_run, 1, "the pre-failure flight served normally");
        let rb = r.rebalance.expect("the drain reaches the failure instant");
        assert_eq!(rb.failed_node, 0);
        assert_eq!(rb.cache_entries_lost, 1, "the completed flight's entry was resident");
        assert!(!r.per_node[0].alive);
        assert_eq!(r.per_node[0].cache_entries, 0);
    }

    #[test]
    fn all_nodes_dead_sheds_everything() {
        let suite = tasks::kernelbench();
        let trace = vec![TrafficRequest {
            task_index: 0,
            gpu: gpu::by_key("rtx6000").unwrap(),
            priority: Priority::Standard,
            tenant: 0,
            arrival_s: 10.0,
        }];
        let mut cluster = ClusterService::new(ClusterConfig {
            nodes: 1,
            fail_node_at: Some((0, 0.0)),
            service: ServiceConfig { threads: 1, ..ServiceConfig::default() },
            ..ClusterConfig::default()
        });
        let r = cluster.replay(&trace, &suite, &NoOracle);
        assert_eq!(r.overall.rejected, 1, "an unroutable request is shed");
        assert_eq!(r.overall.flights_run, 0);
        assert!(!r.per_node[0].alive);
        // The unroutable shed still counts as the tenant's traffic.
        assert_eq!(r.per_tenant[0].requests, 1);
        assert_eq!(r.per_tenant[0].rejected, 1);
        assert_eq!(r.per_tenant[0].served, 0);
    }
}
