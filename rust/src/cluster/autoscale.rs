//! Closed-loop autoscaling policies over the cluster's membership.
//!
//! A replay under autoscaling ([`super::ClusterService::replay_autoscaled`])
//! pauses at simulated **decision ticks** (every
//! [`AutoscaleConfig::tick_s`] seconds), snapshots per-node rolling signals
//! into a [`TickSignals`], and asks an [`AutoscalePolicy`] how many nodes to
//! add or drop. The [`AutoscaleRun`] turns that integer into concrete
//! [`MembershipEvent`]s — fails land immediately, joins land after the
//! configured provisioning delay — and the replay feeds them through the
//! **same** epoch-versioned membership machinery scripted events use, so
//! every policy decision is automatically priced: cache-entry losses,
//! transfer gaps, refill billing, and the per-event
//! [`super::RebalanceReport`] all come for free.
//!
//! Everything here is deterministic: policies see only simulated-time
//! signals (never wall-clock or thread counts), so a policy run inherits
//! the replay's bit-identity contracts across OS `threads` and `window`
//! sizes. The [`StaticPolicy`] never acts, which makes an autoscaled replay
//! under it bit-identical to a plain [`super::ClusterService::replay`] —
//! the anchor the integration tests pin.

use crate::cluster::{MembershipChange, MembershipEvent};

/// One node's rolling signals at a decision tick.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NodeSignals {
    /// Node slot index.
    pub node: usize,
    /// Whether the node is alive at the tick instant.
    pub alive: bool,
    /// Busy-seconds accrued since the previous tick divided by the node's
    /// worker-seconds of capacity over the same span. Service time accrues
    /// at flight *start* (the fleet's rolling-utilization convention), so a
    /// long flight shows up entirely in the tick that admitted it.
    pub utilization: f64,
    /// Flights waiting in the node's queue at the tick instant.
    pub backlog: usize,
}

/// Everything a policy may observe at one decision tick. All fields are
/// functions of simulated time only.
#[derive(Clone, Debug, PartialEq)]
pub struct TickSignals {
    /// The tick's simulated instant.
    pub at_s: f64,
    /// Seconds since the previous tick (equals the tick period except for
    /// a first tick after a warm restore).
    pub elapsed_s: f64,
    /// Alive nodes at the tick instant.
    pub alive_nodes: usize,
    /// Total worker slots across alive nodes.
    pub total_slots: usize,
    /// Per-node signals, indexed by slot (dead nodes included, marked).
    pub per_node: Vec<NodeSignals>,
    /// Total queued flights across alive nodes.
    pub backlog_total: usize,
    /// Mean utilization across alive nodes (0 if none are alive).
    pub mean_utilization: f64,
    /// Fraction of requests *completed since the previous tick* that met
    /// their priority's SLO target; 1.0 when nothing completed (an idle
    /// window is not an SLO violation).
    pub slo_attainment: f64,
    /// Requests completed since the previous tick.
    pub served_window: u64,
    /// Requests that had arrived by the tick instant, since replay start.
    pub arrivals_window: usize,
}

/// A deterministic sizing policy: observe a tick, answer with a signed
/// node delta (`+n` schedule n joins, `-n` schedule n fails, `0` hold).
/// The [`AutoscaleRun`] clamps the answer to the fleet's actual headroom,
/// so policies may answer optimistically.
pub trait AutoscalePolicy {
    /// The policy's CLI/report name.
    fn name(&self) -> &'static str;
    /// Decide a node delta for this tick. `&mut self` so policies can keep
    /// internal state (cooldowns, last-direction hysteresis) — but that
    /// state must itself be a function of the observed signal sequence.
    fn decide(&mut self, signals: &TickSignals) -> i64;
}

/// The do-nothing baseline: the fleet stays whatever size it started at.
#[derive(Clone, Copy, Debug, Default)]
pub struct StaticPolicy;

impl AutoscalePolicy for StaticPolicy {
    fn name(&self) -> &'static str {
        "static"
    }
    fn decide(&mut self, _signals: &TickSignals) -> i64 {
        0
    }
}

/// Threshold/hysteresis on rolling utilization and backlog depth: scale up
/// when mean utilization or per-node backlog crosses the high-water mark,
/// scale down only when utilization is below the low-water mark *and* the
/// queues are empty, and hold for `cooldown_ticks` after any action so one
/// burst doesn't cause a join/fail flap.
#[derive(Clone, Copy, Debug)]
pub struct ThresholdPolicy {
    /// Scale up when mean utilization exceeds this.
    pub util_high: f64,
    /// Scale down only when mean utilization is below this.
    pub util_low: f64,
    /// Scale up when queued flights per alive node exceed this.
    pub backlog_high: f64,
    /// Ticks to hold after acting (the hysteresis half of the policy).
    pub cooldown_ticks: usize,
    cooldown: usize,
}

impl Default for ThresholdPolicy {
    fn default() -> Self {
        ThresholdPolicy {
            util_high: 0.75,
            util_low: 0.20,
            backlog_high: 4.0,
            cooldown_ticks: 1,
            cooldown: 0,
        }
    }
}

impl ThresholdPolicy {
    /// Build a fully-parameterized threshold policy (the `cooldown` counter
    /// itself is internal state and starts at zero).
    pub fn new(
        util_high: f64,
        util_low: f64,
        backlog_high: f64,
        cooldown_ticks: usize,
    ) -> ThresholdPolicy {
        ThresholdPolicy { util_high, util_low, backlog_high, cooldown_ticks, cooldown: 0 }
    }
}

impl AutoscalePolicy for ThresholdPolicy {
    fn name(&self) -> &'static str {
        "threshold"
    }
    fn decide(&mut self, s: &TickSignals) -> i64 {
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return 0;
        }
        let per_node_backlog = if s.alive_nodes > 0 {
            s.backlog_total as f64 / s.alive_nodes as f64
        } else {
            s.backlog_total as f64
        };
        if s.mean_utilization > self.util_high || per_node_backlog > self.backlog_high {
            self.cooldown = self.cooldown_ticks;
            1
        } else if s.mean_utilization < self.util_low && s.backlog_total == 0 {
            self.cooldown = self.cooldown_ticks;
            -1
        } else {
            0
        }
    }
}

/// Target-tracking on windowed SLO attainment: scale up whenever the
/// fraction of requests completed since the last tick that met their SLO
/// drops below `target_attainment`; scale down when attainment holds *and*
/// the fleet is so idle (below `util_floor`, empty queues) that shedding a
/// node can't plausibly cost the target.
#[derive(Clone, Copy, Debug)]
pub struct TargetTrackingPolicy {
    /// Windowed SLO attainment to defend.
    pub target_attainment: f64,
    /// Scale down only when mean utilization is below this.
    pub util_floor: f64,
    /// Ticks to hold after acting.
    pub cooldown_ticks: usize,
    cooldown: usize,
}

impl Default for TargetTrackingPolicy {
    fn default() -> Self {
        TargetTrackingPolicy {
            target_attainment: 0.95,
            util_floor: 0.25,
            cooldown_ticks: 1,
            cooldown: 0,
        }
    }
}

impl TargetTrackingPolicy {
    /// Build a fully-parameterized target-tracking policy (the `cooldown`
    /// counter itself is internal state and starts at zero).
    pub fn new(
        target_attainment: f64,
        util_floor: f64,
        cooldown_ticks: usize,
    ) -> TargetTrackingPolicy {
        TargetTrackingPolicy { target_attainment, util_floor, cooldown_ticks, cooldown: 0 }
    }
}

impl AutoscalePolicy for TargetTrackingPolicy {
    fn name(&self) -> &'static str {
        "target-tracking"
    }
    fn decide(&mut self, s: &TickSignals) -> i64 {
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return 0;
        }
        if s.slo_attainment < self.target_attainment {
            self.cooldown = self.cooldown_ticks;
            1
        } else if s.mean_utilization < self.util_floor && s.backlog_total == 0 {
            self.cooldown = self.cooldown_ticks;
            -1
        } else {
            0
        }
    }
}

/// Look a policy up by its CLI name (`static`, `threshold`,
/// `target-tracking`), with default parameters.
pub fn policy_by_name(name: &str) -> Option<Box<dyn AutoscalePolicy>> {
    match name {
        "static" => Some(Box::new(StaticPolicy)),
        "threshold" => Some(Box::<ThresholdPolicy>::default()),
        "target-tracking" => Some(Box::<TargetTrackingPolicy>::default()),
        _ => None,
    }
}

/// Every policy name [`policy_by_name`] accepts, in presentation order.
pub const POLICY_NAMES: [&str; 3] = ["static", "threshold", "target-tracking"];

/// Knobs shared by every policy run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AutoscaleConfig {
    /// Seconds between decision ticks.
    pub tick_s: f64,
    /// Simulated seconds between a join decision and the capacity landing
    /// (instance boot + image pull + cache-server attach). Fails are
    /// immediate — capacity you drop is gone now.
    pub provision_delay_s: f64,
    /// Never fail the fleet below this many alive nodes.
    pub min_nodes: usize,
    /// Never join the fleet above this many alive-or-provisioning nodes
    /// (additionally capped by the cluster's configured node-slot count).
    pub max_nodes: usize,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            tick_s: 3600.0,
            provision_delay_s: 600.0,
            min_nodes: 1,
            max_nodes: usize::MAX,
        }
    }
}

/// One concrete action a policy took: the decision instant, the instant
/// the resulting membership event lands, and the event itself.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScheduledAction {
    /// Tick instant the policy decided at.
    pub decided_at_s: f64,
    /// Instant the membership event fires (`decided_at_s` for fails,
    /// `decided_at_s + provision_delay_s` for joins).
    pub at_s: f64,
    /// Node slot acted on.
    pub node: usize,
    /// Whether the node fails or joins.
    pub change: MembershipChange,
}

/// The mutable state of one policy run: the policy, its tick cursor, the
/// rolling-signal baselines, and the action log. Owned by the caller and
/// threaded through [`super::ClusterService::replay_autoscaled`]; after the
/// replay, [`AutoscaleRun::actions`] holds every event the policy emitted.
pub struct AutoscaleRun {
    /// The run's knobs.
    pub config: AutoscaleConfig,
    policy: Box<dyn AutoscalePolicy>,
    /// 1-based index of the next tick to fire (tick k fires at `k * tick_s`).
    next_tick: u64,
    /// Joins scheduled but not yet landed (their `at_s` is in the future).
    pending_joins: Vec<MembershipEvent>,
    prev_busy: Vec<f64>,
    prev_served: u64,
    prev_ok: u64,
    last_tick_s: f64,
    /// Every action the policy took, in decision order.
    pub actions: Vec<ScheduledAction>,
    /// Decision ticks fired so far.
    pub ticks: usize,
    /// The signals the most recent tick observed — what the flight
    /// recorder stamps into its `autoscale.tick` event.
    pub(crate) last_signals: Option<TickSignals>,
}

impl AutoscaleRun {
    /// Wrap a policy and config into a fresh run.
    pub fn new(policy: Box<dyn AutoscalePolicy>, config: AutoscaleConfig) -> AutoscaleRun {
        assert!(
            config.tick_s.is_finite() && config.tick_s > 0.0,
            "autoscale tick must be finite and positive, got {}",
            config.tick_s
        );
        AutoscaleRun {
            config,
            policy,
            next_tick: 1,
            pending_joins: Vec::new(),
            prev_busy: Vec::new(),
            prev_served: 0,
            prev_ok: 0,
            last_tick_s: 0.0,
            actions: Vec::new(),
            ticks: 0,
            last_signals: None,
        }
    }

    /// The wrapped policy's name.
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Joins the policy has scheduled this run.
    pub fn joins(&self) -> usize {
        self.actions.iter().filter(|a| a.change == MembershipChange::Join).count()
    }

    /// Fails the policy has scheduled this run.
    pub fn fails(&self) -> usize {
        self.actions.iter().filter(|a| a.change == MembershipChange::Fail).count()
    }

    /// The next decision tick due at or before `now`, if any.
    pub(crate) fn next_due(&self, now: f64) -> Option<f64> {
        let at = self.next_tick as f64 * self.config.tick_s;
        (at <= now).then_some(at)
    }

    /// Fire the tick at `at_s`: build the [`TickSignals`] from the raw
    /// per-node state, ask the policy, clamp its answer to the fleet's
    /// headroom, and return the membership events to schedule. `alive`,
    /// `busy_s`, and `depths` are indexed by node slot; `served`/`slo_ok`
    /// are since-replay-start completion counters and `arrivals` the
    /// number of requests that have arrived so far.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn observe(
        &mut self,
        at_s: f64,
        alive: &[bool],
        busy_s: &[f64],
        depths: &[usize],
        workers_per_node: usize,
        served: u64,
        slo_ok: u64,
        arrivals: usize,
    ) -> Vec<MembershipEvent> {
        self.next_tick += 1;
        self.ticks += 1;
        let elapsed_s = at_s - self.last_tick_s;
        self.prev_busy.resize(busy_s.len(), 0.0);

        let capacity_s = workers_per_node as f64 * elapsed_s;
        let per_node: Vec<NodeSignals> = (0..busy_s.len())
            .map(|node| NodeSignals {
                node,
                alive: alive[node],
                utilization: if capacity_s > 0.0 {
                    (busy_s[node] - self.prev_busy[node]) / capacity_s
                } else {
                    0.0
                },
                backlog: depths[node],
            })
            .collect();
        let alive_nodes = per_node.iter().filter(|n| n.alive).count();
        let backlog_total: usize = per_node.iter().filter(|n| n.alive).map(|n| n.backlog).sum();
        let mean_utilization = if alive_nodes > 0 {
            per_node.iter().filter(|n| n.alive).map(|n| n.utilization).sum::<f64>()
                / alive_nodes as f64
        } else {
            0.0
        };
        let served_window = served - self.prev_served;
        let slo_attainment = if served_window > 0 {
            (slo_ok - self.prev_ok) as f64 / served_window as f64
        } else {
            1.0
        };
        let signals = TickSignals {
            at_s,
            elapsed_s,
            alive_nodes,
            total_slots: alive_nodes * workers_per_node,
            per_node,
            backlog_total,
            mean_utilization,
            slo_attainment,
            served_window,
            arrivals_window: arrivals,
        };

        self.prev_busy.copy_from_slice(busy_s);
        self.prev_served = served;
        self.prev_ok = slo_ok;
        self.last_tick_s = at_s;

        let want = self.policy.decide(&signals);
        self.last_signals = Some(signals);
        self.pending_joins.retain(|ev| ev.at_s > at_s);

        let mut out = Vec::new();
        if want > 0 {
            // Planned-alive = alive now + joins still in flight; never
            // provision past max_nodes or past the configured slot count.
            let ceiling = self.config.max_nodes.min(alive.len());
            let planned = alive_nodes + self.pending_joins.len();
            let room = ceiling.saturating_sub(planned);
            let mut to_add = (want as usize).min(room);
            for node in 0..alive.len() {
                if to_add == 0 {
                    break;
                }
                if !alive[node] && !self.has_pending(node) {
                    let ev = MembershipEvent::join(
                        node,
                        at_s + self.config.provision_delay_s.max(0.0),
                    );
                    self.pending_joins.push(ev);
                    self.actions.push(ScheduledAction {
                        decided_at_s: at_s,
                        at_s: ev.at_s,
                        node,
                        change: MembershipChange::Join,
                    });
                    out.push(ev);
                    to_add -= 1;
                }
            }
        } else if want < 0 {
            // Clamp against both the planned size (so we don't decide our
            // way below min_nodes counting in-flight joins) and the live
            // size (so we never fail a node that isn't actually alive).
            let planned = alive_nodes + self.pending_joins.len();
            let mut to_drop = ((-want) as usize)
                .min(planned.saturating_sub(self.config.min_nodes))
                .min(alive_nodes.saturating_sub(self.config.min_nodes));
            for node in (0..alive.len()).rev() {
                if to_drop == 0 {
                    break;
                }
                if alive[node] && !self.has_pending(node) {
                    let ev = MembershipEvent::fail(node, at_s);
                    self.actions.push(ScheduledAction {
                        decided_at_s: at_s,
                        at_s,
                        node,
                        change: MembershipChange::Fail,
                    });
                    out.push(ev);
                    to_drop -= 1;
                }
            }
        }
        out
    }

    fn has_pending(&self, node: usize) -> bool {
        self.pending_joins.iter().any(|ev| ev.node == node)
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    fn tick(run: &mut AutoscaleRun, at_s: f64, alive: &[bool], busy: &[f64], depths: &[usize]) -> Vec<MembershipEvent> {
        run.observe(at_s, alive, busy, depths, 2, 0, 0, 0)
    }

    #[test]
    fn static_policy_never_acts() {
        let mut run = AutoscaleRun::new(Box::new(StaticPolicy), AutoscaleConfig::default());
        for k in 1..=10u64 {
            let evs = tick(
                &mut run,
                k as f64 * 3600.0,
                &[true, true, false],
                &[1e6, 1e6, 0.0],
                &[50, 50, 0],
            );
            assert!(evs.is_empty());
        }
        assert_eq!(run.ticks, 10);
        assert!(run.actions.is_empty());
    }

    #[test]
    fn threshold_scales_up_on_hot_fleet_and_down_on_idle() {
        let policy = ThresholdPolicy { cooldown_ticks: 0, ..ThresholdPolicy::default() };
        let mut run = AutoscaleRun::new(Box::new(policy), AutoscaleConfig::default());
        // Tick 1: two alive nodes fully busy (2 workers * 3600 s each).
        let evs = tick(&mut run, 3600.0, &[true, true, false], &[7200.0, 7200.0, 0.0], &[0, 0, 0]);
        assert_eq!(evs, vec![MembershipEvent::join(2, 3600.0 + 600.0)], "hot fleet joins the first dead slot, after the provisioning delay");
        // Tick 2: node 2's join landed at 4200 s, and the fleet is now
        // idle (no new busy-seconds, empty queues) — shed the
        // highest-indexed alive node.
        let evs = tick(&mut run, 7200.0, &[true, true, true], &[7200.0, 7200.0, 0.0], &[0, 0, 0]);
        assert_eq!(evs, vec![MembershipEvent::fail(2, 7200.0)]);
        assert_eq!(run.joins(), 1);
        assert_eq!(run.fails(), 1);
    }

    #[test]
    fn threshold_scales_up_on_backlog_even_when_util_is_low() {
        let policy = ThresholdPolicy { cooldown_ticks: 0, ..ThresholdPolicy::default() };
        let mut run = AutoscaleRun::new(Box::new(policy), AutoscaleConfig::default());
        let evs = tick(&mut run, 3600.0, &[true, false], &[0.0, 0.0], &[9, 0]);
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].change, MembershipChange::Join);
    }

    #[test]
    fn cooldown_suppresses_the_next_decision() {
        let policy = ThresholdPolicy { cooldown_ticks: 1, ..ThresholdPolicy::default() };
        let mut run = AutoscaleRun::new(Box::new(policy), AutoscaleConfig::default());
        let hot = [14400.0, 14400.0];
        assert_eq!(tick(&mut run, 3600.0, &[true, false], &[7200.0, 0.0], &[0, 0]).len(), 1);
        // Still hot, but cooling down: no action. (Busy grows so util stays high.)
        assert!(tick(&mut run, 7200.0, &[true, true], &hot, &[0, 0]).is_empty());
        assert_eq!(run.actions.len(), 1);
    }

    #[test]
    fn target_tracking_defends_attainment_and_sheds_idle_capacity() {
        let policy = TargetTrackingPolicy { cooldown_ticks: 0, ..TargetTrackingPolicy::default() };
        let mut run = AutoscaleRun::new(Box::new(policy), AutoscaleConfig::default());
        // 10 served, only 5 in SLO → attainment 0.5 < 0.95 → join.
        let evs = run.observe(3600.0, &[true, false], &[100.0, 0.0], &[0, 0], 2, 10, 5, 10);
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].change, MembershipChange::Join);
        // Next window: everything in SLO, fleet idle → fail.
        let evs = run.observe(7200.0, &[true, true], &[100.0, 0.0], &[0, 0], 2, 20, 15, 20);
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].change, MembershipChange::Fail);
        // Idle window with zero completions counts as attainment 1.0.
        let evs = run.observe(10800.0, &[true, false], &[100.0, 0.0], &[0, 0], 2, 20, 15, 20);
        assert_eq!(evs.len(), 1, "still idle: sheds again toward min_nodes");
        assert_eq!(evs[0].change, MembershipChange::Fail);
        // At min_nodes (1 alive): the clamp stops further sheds.
        let evs = run.observe(14400.0, &[false, false], &[100.0, 0.0], &[0, 0], 2, 20, 15, 20);
        assert!(evs.is_empty() || evs.iter().all(|e| e.change != MembershipChange::Fail));
    }

    #[test]
    fn clamps_respect_min_max_and_pending_joins() {
        struct Always(i64);
        impl AutoscalePolicy for Always {
            fn name(&self) -> &'static str {
                "always"
            }
            fn decide(&mut self, _s: &TickSignals) -> i64 {
                self.0
            }
        }
        // max_nodes 2 over 4 slots, 1 alive: a +10 answer adds exactly 1.
        let cfg = AutoscaleConfig { max_nodes: 2, ..AutoscaleConfig::default() };
        let mut run = AutoscaleRun::new(Box::new(Always(10)), cfg);
        let evs = tick(&mut run, 3600.0, &[true, false, false, false], &[0.0; 4], &[0; 4]);
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].node, 1, "lowest-indexed dead slot joins first");
        // Same tick period, join still pending (delay 600 → lands at 4200):
        // planned size is already at max, so nothing more is added.
        let evs = tick(&mut run, 4100.0, &[true, false, false, false], &[0.0; 4], &[0; 4]);
        assert!(evs.is_empty(), "pending join counts against max_nodes");

        // min_nodes 2, 3 alive: a -10 answer drops exactly 1, highest first.
        let cfg = AutoscaleConfig { min_nodes: 2, ..AutoscaleConfig::default() };
        let mut run = AutoscaleRun::new(Box::new(Always(-10)), cfg);
        let evs = tick(&mut run, 3600.0, &[true, true, true], &[0.0; 3], &[0; 3]);
        assert_eq!(evs, vec![MembershipEvent::fail(2, 3600.0)]);
    }

    #[test]
    fn utilization_is_busy_delta_over_capacity() {
        use std::cell::RefCell;
        use std::rc::Rc;
        struct Capture(Rc<RefCell<Option<TickSignals>>>);
        impl AutoscalePolicy for Capture {
            fn name(&self) -> &'static str {
                "capture"
            }
            fn decide(&mut self, s: &TickSignals) -> i64 {
                *self.0.borrow_mut() = Some(s.clone());
                0
            }
        }
        let cell = Rc::new(RefCell::new(None));
        let mut run =
            AutoscaleRun::new(Box::new(Capture(Rc::clone(&cell))), AutoscaleConfig::default());
        // 2 workers/node, 3600 s window → capacity 7200 s. Node 0 accrued
        // 3600 busy-seconds → util 0.5; node 1 dead, excluded from the mean.
        run.observe(3600.0, &[true, false], &[3600.0, 0.0], &[3, 0], 2, 4, 4, 7);
        let sig = cell.borrow().clone().unwrap();
        assert_eq!(sig.mean_utilization, 0.5);
        assert_eq!(sig.elapsed_s, 3600.0);
        // Second window: node 0 adds 1800 more busy-seconds → util 0.25.
        // Served goes 4→6 with SLO-ok 4→5 → attainment 0.5 in the window.
        run.observe(7200.0, &[true, false], &[5400.0, 0.0], &[1, 0], 2, 6, 5, 9);
        let sig = cell.borrow().clone().unwrap();
        assert_eq!(sig.mean_utilization, 0.25);
        assert_eq!(sig.per_node[0].utilization, 0.25);
        assert!(!sig.per_node[1].alive);
        assert_eq!(sig.served_window, 2);
        assert_eq!(sig.slo_attainment, 0.5);
        assert_eq!(sig.backlog_total, 1);
        assert_eq!(sig.arrivals_window, 9);
    }
}
