//! Rendezvous (highest-random-weight) routing of fingerprints onto nodes.
//!
//! Every (fingerprint, node) pair gets a pseudo-random score; a fingerprint
//! is owned by the alive node with the highest score. Two properties make
//! this the right shape for the cluster simulation:
//!
//! - **Minimal disruption.** When a node dies, *only* the keys it owned
//!   move (each to its runner-up node); every other key keeps its owner.
//!   Consistent-hash rings need virtual nodes to approximate this —
//!   rendezvous hashing gives it exactly, with no ring state to maintain.
//! - **Determinism.** Scores are FNV-1a over the fingerprint and node index
//!   (the same digest family `service::fingerprint` uses), so routing is a
//!   pure function of (fingerprint, alive set) — replays are bit-stable and
//!   no coordinator process needs simulating.
//!
//! Scores are compared as `(score, node)` so even a (vanishingly unlikely)
//! 64-bit score tie breaks deterministically.
//!
//! [`Membership`] is the mutable half of routing: which node slots are
//! currently alive, plus a monotonically increasing **epoch** that counts
//! membership changes. The epoch never affects where a key routes (routing
//! is a pure function of the alive set); it exists so that *state derived
//! from a membership* — most importantly shard snapshots — can declare
//! which membership history produced it, and so operators can see at a
//! glance whether two cluster states are comparable.

use crate::service::fingerprint::{fnv_extend, Fingerprint, FNV_OFFSET};

/// The cluster's mutable membership: per-slot aliveness plus an epoch
/// counter bumped by every applied change. Node *slots* are fixed at
/// construction (the router hashes over slot indices); membership only
/// toggles which slots currently serve traffic.
#[derive(Clone, Debug, PartialEq)]
pub struct Membership {
    alive: Vec<bool>,
    epoch: u64,
}

impl Membership {
    /// A fresh membership with every one of `nodes` slots alive, at epoch 0
    /// (`nodes` is clamped to at least 1).
    pub fn all_alive(nodes: usize) -> Membership {
        Membership { alive: vec![true; nodes.max(1)], epoch: 0 }
    }

    /// Rebuild a membership at an explicit epoch — how a snapshot restore
    /// resumes the epoch history its manifest recorded.
    pub fn with_epoch(nodes: usize, epoch: u64) -> Membership {
        Membership { epoch, ..Membership::all_alive(nodes) }
    }

    /// [`Membership::with_epoch`], with the listed slots starting dead.
    /// Starting state is not a membership *change*, so the epoch is taken
    /// as given (out-of-range slots in `dead` are ignored).
    pub fn with_dead(nodes: usize, dead: &[usize], epoch: u64) -> Membership {
        let mut m = Membership::with_epoch(nodes, epoch);
        for n in dead {
            if let Some(slot) = m.alive.get_mut(*n) {
                *slot = false;
            }
        }
        m
    }

    /// Total node slots (alive or not).
    pub fn nodes(&self) -> usize {
        self.alive.len()
    }

    /// The alive mask, in the shape [`Router::route`] consumes.
    pub fn alive(&self) -> &[bool] {
        &self.alive
    }

    /// Whether slot `node` is currently alive (out-of-range slots are not).
    pub fn is_alive(&self, node: usize) -> bool {
        self.alive.get(node).copied().unwrap_or(false)
    }

    /// How many slots are currently alive.
    pub fn alive_count(&self) -> usize {
        self.alive.iter().filter(|a| **a).count()
    }

    /// Membership changes applied so far (including any history a snapshot
    /// restore resumed).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Mark slot `node` alive or dead. A no-op change (already in that
    /// state, or out of range) returns `false` and does *not* bump the
    /// epoch; an applied change returns `true` and does.
    pub fn set_alive(&mut self, node: usize, alive: bool) -> bool {
        match self.alive.get_mut(node) {
            Some(slot) if *slot != alive => {
                *slot = alive;
                self.epoch += 1;
                true
            }
            _ => false,
        }
    }
}

/// Stateless rendezvous router over `nodes` simulated nodes.
#[derive(Clone, Copy, Debug)]
pub struct Router {
    nodes: usize,
}

impl Router {
    /// `nodes` is clamped to at least 1.
    pub fn new(nodes: usize) -> Router {
        Router { nodes: nodes.max(1) }
    }

    /// Node slots this router hashes over.
    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// The rendezvous score of `fp` on `node`.
    pub fn score(fp: Fingerprint, node: usize) -> u64 {
        let h = fnv_extend(FNV_OFFSET, &fp.0.to_le_bytes());
        fnv_extend(h, &(node as u64).to_le_bytes())
    }

    /// Owner of `fp` among nodes where `alive[node]` holds. `None` when no
    /// node is alive (the caller sheds the request). `alive.len()` must
    /// equal `nodes`.
    pub fn route(&self, fp: Fingerprint, alive: &[bool]) -> Option<usize> {
        debug_assert_eq!(alive.len(), self.nodes);
        (0..self.nodes)
            .filter(|n| alive.get(*n).copied().unwrap_or(false))
            .max_by_key(|n| (Self::score(fp, *n), *n))
    }

    /// Owner of `fp` with every node alive — what routing *would* do absent
    /// failures. Comparing against [`Router::route`] identifies requests
    /// displaced by a dead node (the rebalanced keys).
    pub fn route_any(&self, fp: Fingerprint) -> usize {
        (0..self.nodes)
            .max_by_key(|n| (Self::score(fp, *n), *n))
            .expect("router has at least one node")
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn routes_are_deterministic_and_in_range() {
        let r = Router::new(5);
        let alive = vec![true; 5];
        for k in 0..1000u64 {
            let fp = Fingerprint(k.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let a = r.route(fp, &alive).unwrap();
            let b = r.route(fp, &alive).unwrap();
            assert_eq!(a, b);
            assert!(a < 5);
            assert_eq!(a, r.route_any(fp));
        }
    }

    #[test]
    fn load_spreads_across_nodes() {
        let r = Router::new(4);
        let alive = vec![true; 4];
        let mut counts = [0usize; 4];
        for k in 0..4000u64 {
            let fp = Fingerprint(k.wrapping_mul(0x2545_F491_4F6C_DD1D));
            counts[r.route(fp, &alive).unwrap()] += 1;
        }
        for (n, c) in counts.iter().enumerate() {
            // Expected 1000 per node; rendezvous over a good hash stays
            // well within +/- 20%.
            assert!((800..1200).contains(c), "node {n} owns {c} of 4000");
        }
    }

    #[test]
    fn killing_a_node_moves_only_its_keys() {
        let r = Router::new(4);
        let all = vec![true; 4];
        let mut without2 = vec![true; 4];
        without2[2] = false;
        for k in 0..2000u64 {
            let fp = Fingerprint(k.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xABCD);
            let before = r.route(fp, &all).unwrap();
            let after = r.route(fp, &without2).unwrap();
            if before == 2 {
                assert_ne!(after, 2, "orphaned keys must rehash elsewhere");
            } else {
                assert_eq!(before, after, "keys on surviving nodes never move");
            }
        }
    }

    #[test]
    fn membership_epoch_counts_only_applied_changes() {
        let mut m = Membership::all_alive(3);
        assert_eq!((m.nodes(), m.alive_count(), m.epoch()), (3, 3, 0));
        assert!(m.set_alive(1, false), "killing an alive node is a change");
        assert_eq!(m.epoch(), 1);
        assert!(!m.is_alive(1));
        assert!(!m.set_alive(1, false), "already dead: no-op, no epoch bump");
        assert_eq!(m.epoch(), 1);
        assert!(m.set_alive(1, true), "rejoin is a change");
        assert_eq!(m.epoch(), 2);
        assert!(!m.set_alive(7, false), "out-of-range slots are untouchable");
        assert_eq!(m.epoch(), 2);
        // A restored membership resumes its manifest's epoch history.
        let r = Membership::with_epoch(2, 9);
        assert_eq!((r.nodes(), r.epoch(), r.alive_count()), (2, 9, 2));
    }

    #[test]
    fn no_alive_node_routes_nowhere() {
        let r = Router::new(3);
        assert_eq!(r.route(Fingerprint(7), &[false, false, false]), None);
        assert_eq!(r.route(Fingerprint(7), &[false, true, false]), Some(1));
        assert_eq!(Router::new(1).route(Fingerprint(9), &[true]), Some(0));
    }
}
