//! Rendezvous (highest-random-weight) routing of fingerprints onto nodes.
//!
//! Every (fingerprint, node) pair gets a pseudo-random score; a fingerprint
//! is owned by the alive node with the highest score. Two properties make
//! this the right shape for the cluster simulation:
//!
//! - **Minimal disruption.** When a node dies, *only* the keys it owned
//!   move (each to its runner-up node); every other key keeps its owner.
//!   Consistent-hash rings need virtual nodes to approximate this —
//!   rendezvous hashing gives it exactly, with no ring state to maintain.
//! - **Determinism.** Scores are FNV-1a over the fingerprint and node index
//!   (the same digest family `service::fingerprint` uses), so routing is a
//!   pure function of (fingerprint, alive set) — replays are bit-stable and
//!   no coordinator process needs simulating.
//!
//! Scores are compared as `(score, node)` so even a (vanishingly unlikely)
//! 64-bit score tie breaks deterministically.

use crate::service::fingerprint::{fnv_extend, Fingerprint, FNV_OFFSET};

/// Stateless rendezvous router over `nodes` simulated nodes.
#[derive(Clone, Copy, Debug)]
pub struct Router {
    nodes: usize,
}

impl Router {
    /// `nodes` is clamped to at least 1.
    pub fn new(nodes: usize) -> Router {
        Router { nodes: nodes.max(1) }
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// The rendezvous score of `fp` on `node`.
    pub fn score(fp: Fingerprint, node: usize) -> u64 {
        let h = fnv_extend(FNV_OFFSET, &fp.0.to_le_bytes());
        fnv_extend(h, &(node as u64).to_le_bytes())
    }

    /// Owner of `fp` among nodes where `alive[node]` holds. `None` when no
    /// node is alive (the caller sheds the request). `alive.len()` must
    /// equal `nodes`.
    pub fn route(&self, fp: Fingerprint, alive: &[bool]) -> Option<usize> {
        debug_assert_eq!(alive.len(), self.nodes);
        (0..self.nodes)
            .filter(|n| alive.get(*n).copied().unwrap_or(false))
            .max_by_key(|n| (Self::score(fp, *n), *n))
    }

    /// Owner of `fp` with every node alive — what routing *would* do absent
    /// failures. Comparing against [`Router::route`] identifies requests
    /// displaced by a dead node (the rebalanced keys).
    pub fn route_any(&self, fp: Fingerprint) -> usize {
        (0..self.nodes)
            .max_by_key(|n| (Self::score(fp, *n), *n))
            .expect("router has at least one node")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_are_deterministic_and_in_range() {
        let r = Router::new(5);
        let alive = vec![true; 5];
        for k in 0..1000u64 {
            let fp = Fingerprint(k.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let a = r.route(fp, &alive).unwrap();
            let b = r.route(fp, &alive).unwrap();
            assert_eq!(a, b);
            assert!(a < 5);
            assert_eq!(a, r.route_any(fp));
        }
    }

    #[test]
    fn load_spreads_across_nodes() {
        let r = Router::new(4);
        let alive = vec![true; 4];
        let mut counts = [0usize; 4];
        for k in 0..4000u64 {
            let fp = Fingerprint(k.wrapping_mul(0x2545_F491_4F6C_DD1D));
            counts[r.route(fp, &alive).unwrap()] += 1;
        }
        for (n, c) in counts.iter().enumerate() {
            // Expected 1000 per node; rendezvous over a good hash stays
            // well within +/- 20%.
            assert!((800..1200).contains(c), "node {n} owns {c} of 4000");
        }
    }

    #[test]
    fn killing_a_node_moves_only_its_keys() {
        let r = Router::new(4);
        let all = vec![true; 4];
        let mut without2 = vec![true; 4];
        without2[2] = false;
        for k in 0..2000u64 {
            let fp = Fingerprint(k.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xABCD);
            let before = r.route(fp, &all).unwrap();
            let after = r.route(fp, &without2).unwrap();
            if before == 2 {
                assert_ne!(after, 2, "orphaned keys must rehash elsewhere");
            } else {
                assert_eq!(before, after, "keys on surviving nodes never move");
            }
        }
    }

    #[test]
    fn no_alive_node_routes_nowhere() {
        let r = Router::new(3);
        assert_eq!(r.route(Fingerprint(7), &[false, false, false]), None);
        assert_eq!(r.route(Fingerprint(7), &[false, true, false]), Some(1));
        assert_eq!(Router::new(1).route(Fingerprint(9), &[true]), Some(0));
    }
}
