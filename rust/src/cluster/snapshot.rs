//! Shard-aware cluster snapshots: a manifest plus one JSONL file per shard.
//!
//! The single-node service snapshots its one cache as one JSONL file; a
//! cluster's state is N per-shard caches **plus** the membership history
//! that placed every key — restoring shard files under a different node
//! count silently mis-places every moved key unless the restore knows what
//! it is looking at. The on-disk layout therefore separates *data* from
//! *description*:
//!
//! - `manifest.json` — format version, the cache wire version
//!   ([`crate::service::cache::SNAPSHOT_VERSION`]), the rendezvous
//!   **epoch** (how many membership changes produced this state), the node
//!   count, and the file name + entry count of every shard file and of the
//!   cold-cost registry.
//! - `shard-<i>.jsonl` — node `i`'s cache in the single-node wire format,
//!   its header stamped with `{epoch, shard, nodes}` so each file declares
//!   which manifest it belongs to.
//! - `cold-cost.jsonl` — the cluster-wide per-fingerprint cold-run spend
//!   registry. Counterfactual pricing is cluster state, not shard state:
//!   without it a restored cluster would re-price warm runs against their
//!   own spend and a restored replay could not be bit-identical.
//!
//! Restores are **paranoid by design**: a manifest whose declared shard
//! count, epoch, or entry counts disagree with the files it names is
//! rejected with the offending path in the error chain — a half-copied or
//! hand-edited snapshot directory must fail loudly, not serve a cluster
//! whose shards disagree about history. See `docs/snapshots.md` for the
//! schema and compatibility rules.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::service::cache::{ResultCache, SNAPSHOT_VERSION};
use crate::service::fingerprint::Fingerprint;
use crate::util::json::Json;

/// Manifest wire-format version (the first thing `restore` checks).
pub const MANIFEST_VERSION: u32 = 1;

/// File name of the manifest inside a snapshot directory.
pub const MANIFEST_FILE: &str = "manifest.json";

/// One data file the manifest describes: its name (relative to the
/// snapshot directory) and how many entry lines it holds.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardFile {
    /// File name relative to the snapshot directory.
    pub file: String,
    /// Entry lines the file holds (excluding its header line).
    pub entries: usize,
}

impl ShardFile {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("file", Json::str(self.file.clone())),
            ("entries", Json::num(self.entries as f64)),
        ])
    }

    fn from_json(v: &Json) -> Option<ShardFile> {
        Some(ShardFile {
            file: v.get("file")?.as_str()?.to_string(),
            entries: v.get("entries")?.as_usize()?,
        })
    }
}

/// The snapshot directory's self-description. Everything `restore` needs to
/// decide whether the files are loadable, and how much key movement a
/// membership change since the save implies.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    /// [`MANIFEST_VERSION`] at save time.
    pub manifest_version: u32,
    /// The cache wire version ([`SNAPSHOT_VERSION`]) the shard files use.
    pub snapshot_version: u32,
    /// Rendezvous epoch of the membership that produced this state.
    pub epoch: u64,
    /// Node count the shards were laid out for.
    pub nodes: usize,
    /// Per-shard data files, index-aligned with node slots.
    pub shards: Vec<ShardFile>,
    /// The cluster-wide cold-cost registry file.
    pub cold_cost: ShardFile,
    /// Build stamp of the binary that wrote the snapshot
    /// ([`crate::trace::build_stamp`]): crate version plus enabled
    /// features. Informational — restores gate on the wire versions
    /// above, never on this — but it turns "which build wrote this?"
    /// into a `cat` instead of an archaeology session. Absent in
    /// pre-stamp snapshots (restored as the empty string).
    pub build: String,
}

impl Manifest {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("manifest_version", Json::num(self.manifest_version as f64)),
            ("snapshot_version", Json::num(self.snapshot_version as f64)),
            ("epoch", Json::num(self.epoch as f64)),
            ("nodes", Json::num(self.nodes as f64)),
            (
                "shards",
                Json::Arr(self.shards.iter().map(ShardFile::to_json).collect()),
            ),
            ("cold_cost", self.cold_cost.to_json()),
            ("build", Json::str(self.build.clone())),
        ])
    }

    fn from_json(v: &Json) -> Option<Manifest> {
        Some(Manifest {
            manifest_version: v.get("manifest_version")?.as_usize()? as u32,
            snapshot_version: v.get("snapshot_version")?.as_usize()? as u32,
            epoch: v.get("epoch")?.as_f64()? as u64,
            nodes: v.get("nodes")?.as_usize()?,
            shards: v
                .get("shards")?
                .as_arr()?
                .iter()
                .map(ShardFile::from_json)
                .collect::<Option<Vec<_>>>()?,
            cold_cost: ShardFile::from_json(v.get("cold_cost")?)?,
            // Tolerated when absent: the stamp is informational, and
            // snapshots written before it existed stay loadable.
            build: v
                .get("build")
                .and_then(|b| b.as_str())
                .map(String::from)
                .unwrap_or_default(),
        })
    }
}

/// Whether `dir` looks like a snapshot directory (its manifest exists).
pub fn exists(dir: impl AsRef<Path>) -> bool {
    dir.as_ref().join(MANIFEST_FILE).exists()
}

/// Read and structurally validate `dir`'s manifest: version gates, and the
/// declared shard list must be self-consistent (`shards.len() == nodes`).
/// File-level cross-checks happen in [`load`].
pub fn read_manifest(dir: impl AsRef<Path>) -> Result<Manifest> {
    let path = dir.as_ref().join(MANIFEST_FILE);
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading cluster manifest {}", path.display()))?;
    let v = Json::parse(&text)
        .map_err(|e| anyhow!("cluster manifest {}: {e}", path.display()))?;
    let m = Manifest::from_json(&v)
        .ok_or_else(|| anyhow!("cluster manifest {}: missing fields", path.display()))?;
    if m.manifest_version != MANIFEST_VERSION {
        bail!(
            "cluster manifest {} has manifest_version {} unsupported by this build \
             (which reads {MANIFEST_VERSION}) — delete the snapshot and re-warm",
            path.display(),
            m.manifest_version
        );
    }
    if m.snapshot_version != SNAPSHOT_VERSION {
        bail!(
            "cluster manifest {} declares cache snapshot_version {} but this build \
             reads {SNAPSHOT_VERSION} (fingerprints would never hit) — delete the \
             snapshot and re-warm",
            path.display(),
            m.snapshot_version
        );
    }
    if m.nodes == 0 {
        bail!("cluster manifest {} declares zero nodes", path.display());
    }
    if m.shards.len() != m.nodes {
        bail!(
            "cluster manifest {} declares {} nodes but lists {} shard files — \
             the manifest disagrees with its own file list",
            path.display(),
            m.nodes,
            m.shards.len()
        );
    }
    Ok(m)
}

/// Read `path` once, parse its JSONL header line, and count its entry
/// lines — the cross-check half of a shard restore, run *before* the cache
/// rebuild so a mismatched file is named without partially loading it. The
/// full text is returned so the rebuild consumes the same single read.
fn audit_jsonl(path: &Path) -> Result<(Json, usize, String)> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading snapshot file {}", path.display()))?;
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header_line = lines
        .next()
        .ok_or_else(|| anyhow!("snapshot file {} is empty", path.display()))?;
    let header = Json::parse(header_line)
        .map_err(|e| anyhow!("snapshot file {} header: {e}", path.display()))?;
    let entries = lines.count();
    Ok((header, entries, text))
}

/// Verify one stamped header field against the manifest's declaration.
fn check_header_field(path: &Path, header: &Json, name: &str, want: f64) -> Result<()> {
    match header.get(name).and_then(|v| v.as_f64()) {
        Some(got) if got == want => Ok(()),
        Some(got) => bail!(
            "snapshot shard {} declares {name} {got} but the manifest says {want} — \
             the manifest disagrees with its own file list",
            path.display()
        ),
        None => bail!(
            "snapshot shard {} has no {name} stamp (not written by a cluster \
             snapshot, or truncated)",
            path.display()
        ),
    }
}

/// Write the cluster's shards, cold-cost registry, and manifest into `dir`
/// (created if absent). The manifest is written **last**, so an interrupted
/// save leaves a directory [`exists`] rejects rather than a plausible but
/// incomplete snapshot. Returns the manifest that was written.
pub fn save(
    dir: impl AsRef<Path>,
    caches: &[ResultCache],
    cold_cost: &BTreeMap<Fingerprint, f64>,
    epoch: u64,
) -> Result<Manifest> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating snapshot directory {}", dir.display()))?;
    let nodes = caches.len();
    let mut shards = Vec::with_capacity(nodes);
    for (i, cache) in caches.iter().enumerate() {
        let file = format!("shard-{i}.jsonl");
        cache.snapshot_with_header(
            dir.join(&file),
            vec![
                ("epoch", Json::num(epoch as f64)),
                ("shard", Json::num(i as f64)),
                ("nodes", Json::num(nodes as f64)),
            ],
        )?;
        shards.push(ShardFile { file, entries: cache.len() });
    }

    let cold_file = "cold-cost.jsonl".to_string();
    let cold_path = dir.join(&cold_file);
    let mut out = Json::obj(vec![
        ("snapshot_version", Json::num(SNAPSHOT_VERSION as f64)),
        ("epoch", Json::num(epoch as f64)),
    ])
    .to_string();
    out.push('\n');
    for (fp, usd) in cold_cost {
        out.push_str(
            &Json::obj(vec![
                ("fingerprint", Json::str(fp.to_string())),
                ("cold_api_usd", Json::num(*usd)),
            ])
            .to_string(),
        );
        out.push('\n');
    }
    std::fs::write(&cold_path, out)
        .with_context(|| format!("writing cold-cost registry {}", cold_path.display()))?;

    let manifest = Manifest {
        manifest_version: MANIFEST_VERSION,
        snapshot_version: SNAPSHOT_VERSION,
        epoch,
        nodes,
        shards,
        cold_cost: ShardFile { file: cold_file, entries: cold_cost.len() },
        build: crate::trace::build_stamp(),
    };
    let mpath = dir.join(MANIFEST_FILE);
    std::fs::write(&mpath, format!("{}\n", manifest.to_json()))
        .with_context(|| format!("writing cluster manifest {}", mpath.display()))?;
    Ok(manifest)
}

/// Load a snapshot directory back into per-shard caches (each restored at
/// `capacity`) plus the cold-cost registry, cross-checking every file
/// against the manifest: each shard's stamped epoch / shard index / node
/// count and its entry count must match what the manifest declares, with
/// the offending path in the error chain otherwise. Shard *placement* is
/// exactly as saved — rehashing keys for a different node count is the
/// caller's job (`ClusterService::restore`), which is also where the
/// movement gets accounted.
pub fn load(
    dir: impl AsRef<Path>,
    capacity: usize,
) -> Result<(Manifest, Vec<ResultCache>, BTreeMap<Fingerprint, f64>)> {
    let dir = dir.as_ref();
    let manifest = read_manifest(dir)?;
    let mut caches = Vec::with_capacity(manifest.nodes);
    for (i, shard) in manifest.shards.iter().enumerate() {
        let path: PathBuf = dir.join(&shard.file);
        let (header, n_entries, text) = audit_jsonl(&path)?;
        check_header_field(&path, &header, "epoch", manifest.epoch as f64)?;
        check_header_field(&path, &header, "shard", i as f64)?;
        check_header_field(&path, &header, "nodes", manifest.nodes as f64)?;
        if n_entries != shard.entries {
            bail!(
                "snapshot shard {} holds {n_entries} entries but the manifest \
                 declares {} — the manifest disagrees with its own file list",
                path.display(),
                shard.entries
            );
        }
        caches.push(ResultCache::restore_from_str(&text, capacity, &path)?);
    }

    let cold_path = dir.join(&manifest.cold_cost.file);
    let (header, n_entries, text) = audit_jsonl(&cold_path)?;
    check_header_field(&cold_path, &header, "epoch", manifest.epoch as f64)?;
    if n_entries != manifest.cold_cost.entries {
        bail!(
            "cold-cost registry {} holds {n_entries} entries but the manifest \
             declares {} — the manifest disagrees with its own file list",
            cold_path.display(),
            manifest.cold_cost.entries
        );
    }
    let mut cold_cost = BTreeMap::new();
    for (i, line) in text.lines().enumerate().skip(1) {
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(line).map_err(|e| {
            anyhow!("cold-cost registry {} line {}: {e}", cold_path.display(), i + 1)
        })?;
        let fp = v
            .get("fingerprint")
            .and_then(|x| x.as_str())
            .and_then(Fingerprint::parse);
        let usd = v.get("cold_api_usd").and_then(|x| x.as_f64());
        match (fp, usd) {
            (Some(fp), Some(usd)) => {
                cold_cost.insert(fp, usd);
            }
            _ => bail!(
                "cold-cost registry {} line {}: missing fields",
                cold_path.display(),
                i + 1
            ),
        }
    }
    Ok((manifest, caches, cold_cost))
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::kernel::KernelConfig;
    use crate::service::cache::CacheEntry;

    fn entry(fp: u64, task: &str, gpu: &str) -> CacheEntry {
        CacheEntry {
            fingerprint: Fingerprint(fp),
            task_id: task.to_string(),
            gpu_key: gpu.to_string(),
            strategy: "CudaForge".to_string(),
            coder: "OpenAI-o3".to_string(),
            judge: "OpenAI-o3".to_string(),
            best_speedup: 1.5,
            best_config: KernelConfig::naive(),
            api_usd: 0.30,
            cold_api_usd: 0.30,
            wall_s: 1590.0,
            rounds_to_best: 6,
        }
    }

    fn fresh_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn two_shards() -> (Vec<ResultCache>, BTreeMap<Fingerprint, f64>) {
        let mut a = ResultCache::new(8);
        a.insert(entry(1, "L1-1", "rtx6000"));
        a.insert(entry(2, "L1-2", "rtx6000"));
        let mut b = ResultCache::new(8);
        b.insert(entry(3, "L1-3", "a100"));
        let mut cold = BTreeMap::new();
        cold.insert(Fingerprint(1), 0.31);
        cold.insert(Fingerprint(3), 0.28);
        (vec![a, b], cold)
    }

    #[test]
    fn save_load_round_trips_shards_and_cold_cost() {
        let dir = fresh_dir("cudaforge_snapdir_roundtrip");
        let (caches, cold) = two_shards();
        let m = save(&dir, &caches, &cold, 5).unwrap();
        assert_eq!(m.epoch, 5);
        assert_eq!(m.nodes, 2);
        assert_eq!(m.shards[0].entries, 2);
        assert_eq!(m.shards[1].entries, 1);
        assert_eq!(m.cold_cost.entries, 2);
        assert_eq!(m.build, crate::trace::build_stamp());
        assert!(exists(&dir));

        let (m2, restored, cold2) = load(&dir, 8).unwrap();
        assert_eq!(m2, m);
        assert_eq!(restored.len(), 2);
        assert_eq!(restored[0].len(), 2);
        assert_eq!(restored[1].len(), 1);
        assert_eq!(restored[0].peek(Fingerprint(2)), caches[0].peek(Fingerprint(2)));
        assert_eq!(restored[1].peek(Fingerprint(3)), caches[1].peek(Fingerprint(3)));
        assert_eq!(cold2, cold);
    }

    #[test]
    fn manifest_node_count_must_match_its_shard_list() {
        let dir = fresh_dir("cudaforge_snapdir_nodecount");
        let (caches, cold) = two_shards();
        let mut m = save(&dir, &caches, &cold, 0).unwrap();
        // Corrupt: claim three nodes while listing two shard files.
        m.nodes = 3;
        std::fs::write(dir.join(MANIFEST_FILE), format!("{}\n", m.to_json())).unwrap();
        let err = format!("{:#}", load(&dir, 8).unwrap_err());
        assert!(err.contains("manifest.json"), "{err}");
        assert!(err.contains("disagrees with its own file list"), "{err}");
    }

    #[test]
    fn shard_epoch_stamp_must_match_the_manifest() {
        let dir = fresh_dir("cudaforge_snapdir_epoch");
        let (caches, cold) = two_shards();
        save(&dir, &caches, &cold, 4).unwrap();
        // Re-stamp shard 1 as if it came from a different epoch's save.
        caches[1]
            .snapshot_with_header(
                dir.join("shard-1.jsonl"),
                vec![
                    ("epoch", Json::num(9.0)),
                    ("shard", Json::num(1.0)),
                    ("nodes", Json::num(2.0)),
                ],
            )
            .unwrap();
        let err = format!("{:#}", load(&dir, 8).unwrap_err());
        assert!(err.contains("shard-1.jsonl"), "offending path named: {err}");
        assert!(err.contains("epoch"), "{err}");
    }

    #[test]
    fn entry_count_mismatch_names_the_shard_file() {
        let dir = fresh_dir("cudaforge_snapdir_entrycount");
        let (caches, cold) = two_shards();
        save(&dir, &caches, &cold, 0).unwrap();
        // Truncate shard 0 to its header plus one entry (manifest says 2).
        let text = std::fs::read_to_string(dir.join("shard-0.jsonl")).unwrap();
        let kept: Vec<&str> = text.lines().take(2).collect();
        std::fs::write(dir.join("shard-0.jsonl"), format!("{}\n", kept.join("\n"))).unwrap();
        let err = format!("{:#}", load(&dir, 8).unwrap_err());
        assert!(err.contains("shard-0.jsonl"), "{err}");
        assert!(err.contains("declares 2"), "{err}");
    }

    #[test]
    fn missing_files_and_versions_fail_loudly() {
        let dir = fresh_dir("cudaforge_snapdir_missing");
        assert!(!exists(&dir));
        assert!(read_manifest(&dir).is_err(), "no manifest at all");

        let (caches, cold) = two_shards();
        let mut m = save(&dir, &caches, &cold, 0).unwrap();
        std::fs::remove_file(dir.join("shard-1.jsonl")).unwrap();
        let err = format!("{:#}", load(&dir, 8).unwrap_err());
        assert!(err.contains("shard-1.jsonl"), "{err}");

        // A future manifest version is rejected up front.
        m.manifest_version = MANIFEST_VERSION + 1;
        std::fs::write(dir.join(MANIFEST_FILE), format!("{}\n", m.to_json())).unwrap();
        let err = format!("{:#}", read_manifest(&dir).unwrap_err());
        assert!(err.contains("manifest_version"), "{err}");

        // A cache wire-format mismatch is diagnosed at the manifest, before
        // any shard file is touched.
        m.manifest_version = MANIFEST_VERSION;
        m.snapshot_version = SNAPSHOT_VERSION + 1;
        std::fs::write(dir.join(MANIFEST_FILE), format!("{}\n", m.to_json())).unwrap();
        let err = format!("{:#}", read_manifest(&dir).unwrap_err());
        assert!(err.contains("snapshot_version"), "{err}");
    }
}
