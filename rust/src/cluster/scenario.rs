//! Deterministic traffic/fleet scenarios for autoscaling studies.
//!
//! A [`Scenario`] is a named, parameter-free-to-invoke bundle of three
//! deterministic transforms layered over a generated trace and a cluster
//! config:
//!
//! 1. **Arrival shaping** ([`Scenario::shape_arrivals`]) — a monotone
//!    time-warp applied to the trace's arrival instants. The Zipf/Poisson
//!    generator stays untouched (same seeds, same draws, same
//!    task/GPU/priority/tenant sequence), so the *only* thing a shaper
//!    changes is *when* each request lands. [`Scenario::steady`] is the
//!    identity: it does not touch the trace at all, so a steady-scenario
//!    replay is byte-identical to an unshaped one.
//! 2. **Scripted membership events** ([`Scenario::membership_events`]) —
//!    the correlated mass interruption fails a block of nodes at one
//!    simulated instant, spot-reclaim style.
//! 3. **Per-node service multipliers** ([`Scenario::service_multipliers`])
//!    — the straggler scenario makes one node's workers slower than the
//!    rest (threaded through `FleetSim::set_service_multiplier`).
//!
//! All three transforms are pure functions of the scenario parameters and
//! the input trace — no RNG — so a scenario replay inherits the replay's
//! bit-determinism contracts unchanged.

use crate::cluster::MembershipEvent;
use crate::service::traffic::TrafficRequest;

/// Which shape a [`Scenario`] applies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScenarioKind {
    /// No transform at all: the generated trace replays as-is.
    Steady,
    /// A sinusoidal day/night load cycle: arrivals bunch up around the
    /// peaks of each period and thin out in the troughs.
    Diurnal,
    /// A flash crowd: the middle fifth of the trace's arrivals compress
    /// into a `1/surge`-length burst; later arrivals shift earlier by the
    /// time saved.
    FlashCrowd,
    /// A correlated mass interruption: a block of initially-alive nodes
    /// fails simultaneously a third of the way into the trace (spot
    /// capacity reclaimed in one sweep).
    MassInterruption,
    /// A straggler: node 0's workers take [`Scenario::straggler_multiplier`]
    /// times as long per flight as everyone else's.
    Straggler,
}

/// A deterministic scenario: arrival shaping + scripted membership events +
/// per-node service multipliers. Build one with the named constructors
/// ([`Scenario::diurnal`], …) or [`Scenario::by_name`], then tweak the
/// public parameters if the defaults don't fit.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    /// Which transform family this scenario applies.
    pub kind: ScenarioKind,
    /// Diurnal only: relative rate swing, in `[0, 1)`. 0.8 means the peak
    /// arrival rate is `1/(1-0.8) = 5x` the trough's.
    pub amplitude: f64,
    /// Diurnal only: seconds per load cycle.
    pub period_s: f64,
    /// Flash crowd only: how many times faster the crowd window's arrivals
    /// land (values below 1 are clamped to 1 — a "surge" that slows
    /// traffic down is not a flash crowd).
    pub surge: f64,
    /// Mass interruption only: fraction of the initially-alive fleet that
    /// fails at the interruption instant (clamped so at least one node
    /// survives).
    pub interruption_frac: f64,
    /// Straggler only: node 0's service-time multiplier.
    pub straggler_multiplier: f64,
}

impl Scenario {
    fn base(kind: ScenarioKind) -> Scenario {
        Scenario {
            kind,
            amplitude: 0.8,
            period_s: 6.0 * 3600.0,
            surge: 4.0,
            interruption_frac: 0.5,
            straggler_multiplier: 4.0,
        }
    }

    /// The identity scenario: unshaped arrivals, no events, no multipliers.
    pub fn steady() -> Scenario {
        Scenario::base(ScenarioKind::Steady)
    }

    /// A sinusoidal day/night cycle (amplitude 0.8, 6-hour period).
    pub fn diurnal() -> Scenario {
        Scenario::base(ScenarioKind::Diurnal)
    }

    /// A flash crowd (the middle fifth of arrivals lands 4x faster).
    pub fn flash_crowd() -> Scenario {
        Scenario::base(ScenarioKind::FlashCrowd)
    }

    /// A correlated mass interruption (half the initially-alive nodes fail
    /// a third of the way in).
    pub fn mass_interruption() -> Scenario {
        Scenario::base(ScenarioKind::MassInterruption)
    }

    /// A straggler node (node 0 runs 4x slower).
    pub fn straggler() -> Scenario {
        Scenario::base(ScenarioKind::Straggler)
    }

    /// Every scenario in the pack, in presentation order.
    pub fn all() -> Vec<Scenario> {
        vec![
            Scenario::steady(),
            Scenario::diurnal(),
            Scenario::flash_crowd(),
            Scenario::mass_interruption(),
            Scenario::straggler(),
        ]
    }

    /// Look a scenario up by its CLI name (`steady`, `diurnal`,
    /// `flash-crowd`, `mass-interruption`, `straggler`).
    pub fn by_name(name: &str) -> Option<Scenario> {
        match name {
            "steady" => Some(Scenario::steady()),
            "diurnal" => Some(Scenario::diurnal()),
            "flash-crowd" => Some(Scenario::flash_crowd()),
            "mass-interruption" => Some(Scenario::mass_interruption()),
            "straggler" => Some(Scenario::straggler()),
            _ => None,
        }
    }

    /// The scenario's CLI/report name.
    pub fn name(&self) -> &'static str {
        match self.kind {
            ScenarioKind::Steady => "steady",
            ScenarioKind::Diurnal => "diurnal",
            ScenarioKind::FlashCrowd => "flash-crowd",
            ScenarioKind::MassInterruption => "mass-interruption",
            ScenarioKind::Straggler => "straggler",
        }
    }

    /// Warp the trace's arrival instants in place. The warp is a
    /// closed-form monotone map `w(t)` (no RNG, no iteration-order
    /// dependence), followed by a running-max pass that repairs any
    /// ulp-scale ordering wobble so the trace stays sorted — replays
    /// `debug_assert` sortedness. [`ScenarioKind::Steady`],
    /// [`ScenarioKind::MassInterruption`], and [`ScenarioKind::Straggler`]
    /// leave the slice untouched (not even rewritten), so their traces are
    /// byte-identical to the unshaped ones.
    pub fn shape_arrivals(&self, trace: &mut [TrafficRequest]) {
        match self.kind {
            ScenarioKind::Steady
            | ScenarioKind::MassInterruption
            | ScenarioKind::Straggler => {}
            ScenarioKind::Diurnal => {
                // w(t) = t + (a*P/2pi) * sin(2pi t / P): w'(t) = 1 + a*cos(...)
                // stays positive for a < 1, so the map is strictly monotone;
                // arrival *density* in warped time oscillates between
                // 1/(1+a) and 1/(1-a) of the base rate — the day/night cycle.
                let a = self.amplitude.clamp(0.0, 0.99);
                let p = self.period_s.max(1.0);
                let k = a * p / (2.0 * std::f64::consts::PI);
                for req in trace.iter_mut() {
                    let t = req.arrival_s;
                    req.arrival_s = t + k * (2.0 * std::f64::consts::PI * t / p).sin();
                }
                enforce_sorted(trace);
            }
            ScenarioKind::FlashCrowd => {
                // Compress the arrivals of the base window [0.4T, 0.6T) by
                // `surge`; everything after shifts earlier by the saved
                // time. Piecewise linear, closed form, monotone.
                let span = trace.last().map(|r| r.arrival_s).unwrap_or(0.0);
                if span <= 0.0 {
                    return;
                }
                let surge = self.surge.max(1.0);
                let t0 = 0.4 * span;
                let t1 = 0.6 * span;
                let saved = (t1 - t0) * (1.0 - 1.0 / surge);
                for req in trace.iter_mut() {
                    let t = req.arrival_s;
                    req.arrival_s = if t < t0 {
                        t
                    } else if t < t1 {
                        t0 + (t - t0) / surge
                    } else {
                        t - saved
                    };
                }
                enforce_sorted(trace);
            }
        }
    }

    /// The scenario's scripted membership events, given how many nodes are
    /// alive at replay start and the trace's (shaped) arrival span. Only
    /// the mass interruption scripts anything: it fails the
    /// `interruption_frac` highest-indexed initially-alive nodes at
    /// `span/3`, all at the same instant, leaving at least one survivor.
    pub fn membership_events(&self, alive_nodes: usize, span_s: f64) -> Vec<MembershipEvent> {
        match self.kind {
            ScenarioKind::MassInterruption => {
                let frac = self.interruption_frac.clamp(0.0, 1.0);
                let n_fail = ((alive_nodes as f64 * frac).floor() as usize)
                    .min(alive_nodes.saturating_sub(1));
                let at = (span_s / 3.0).max(0.0);
                (alive_nodes - n_fail..alive_nodes)
                    .map(|node| MembershipEvent::fail(node, at))
                    .collect()
            }
            _ => Vec::new(),
        }
    }

    /// Per-node service-time multipliers over `slots` node slots. Every
    /// scenario but the straggler returns an empty vector (all nodes at
    /// 1.0); the straggler slows node 0 down by
    /// [`Scenario::straggler_multiplier`].
    pub fn service_multipliers(&self, slots: usize) -> Vec<f64> {
        match self.kind {
            ScenarioKind::Straggler if slots > 0 => {
                let mut m = vec![1.0; slots];
                m[0] = self.straggler_multiplier.max(1.0);
                m
            }
            _ => Vec::new(),
        }
    }
}

/// Repair ulp-scale ordering wobble a float warp can introduce between
/// near-equal arrivals: clamp each instant to at least its predecessor's.
fn enforce_sorted(trace: &mut [TrafficRequest]) {
    for i in 1..trace.len() {
        if trace[i].arrival_s < trace[i - 1].arrival_s {
            trace[i].arrival_s = trace[i - 1].arrival_s;
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::cluster::MembershipChange;
    use crate::service::traffic::{generate, TrafficConfig};

    fn base_trace(requests: usize) -> Vec<TrafficRequest> {
        generate(8, &TrafficConfig { requests, seed: 11, ..TrafficConfig::default() })
    }

    fn arrivals(trace: &[TrafficRequest]) -> Vec<f64> {
        trace.iter().map(|r| r.arrival_s).collect()
    }

    #[test]
    fn steady_is_the_identity() {
        let mut shaped = base_trace(300);
        let original = arrivals(&shaped);
        Scenario::steady().shape_arrivals(&mut shaped);
        assert_eq!(arrivals(&shaped), original, "steady must not move a single arrival");
    }

    #[test]
    fn every_shaper_keeps_the_trace_sorted_and_nonnegative() {
        for scenario in Scenario::all() {
            let mut trace = base_trace(400);
            scenario.shape_arrivals(&mut trace);
            assert!(
                trace.windows(2).all(|p| p[0].arrival_s <= p[1].arrival_s),
                "{} must keep arrivals sorted",
                scenario.name()
            );
            assert!(
                trace.iter().all(|r| r.arrival_s >= 0.0),
                "{} must keep arrivals non-negative",
                scenario.name()
            );
        }
    }

    #[test]
    fn shapers_only_move_time_never_content() {
        for scenario in Scenario::all() {
            let original = base_trace(200);
            let mut shaped = original.clone();
            scenario.shape_arrivals(&mut shaped);
            for (a, b) in original.iter().zip(&shaped) {
                assert_eq!(a.task_index, b.task_index);
                assert_eq!(a.gpu.key, b.gpu.key);
                assert_eq!(a.priority, b.priority);
                assert_eq!(a.tenant, b.tenant);
            }
        }
    }

    #[test]
    fn diurnal_warp_is_bounded_by_its_amplitude() {
        let original = base_trace(300);
        let mut shaped = original.clone();
        let s = Scenario::diurnal();
        s.shape_arrivals(&mut shaped);
        // |w(t) - t| <= a*P/2pi by construction.
        let bound = s.amplitude * s.period_s / (2.0 * std::f64::consts::PI) + 1e-9;
        for (a, b) in original.iter().zip(&shaped) {
            assert!((a.arrival_s - b.arrival_s).abs() <= bound);
        }
        assert_ne!(arrivals(&original), arrivals(&shaped), "the warp must actually warp");
    }

    #[test]
    fn flash_crowd_compresses_the_crowd_window() {
        let original = base_trace(500);
        let span = original.last().unwrap().arrival_s;
        let mut shaped = original.clone();
        let s = Scenario::flash_crowd();
        s.shape_arrivals(&mut shaped);
        let in_window = |t: f64| t >= 0.4 * span && t < 0.6 * span;
        let crowd: Vec<(f64, f64)> = original
            .iter()
            .zip(&shaped)
            .filter(|(o, _)| in_window(o.arrival_s))
            .map(|(o, w)| (o.arrival_s, w.arrival_s))
            .collect();
        assert!(crowd.len() > 10, "the fixed seed puts arrivals in the window");
        let base_width = crowd.last().unwrap().0 - crowd.first().unwrap().0;
        let shaped_width = crowd.last().unwrap().1 - crowd.first().unwrap().1;
        assert!(
            shaped_width < base_width / (s.surge * 0.9),
            "crowd window must compress ~{}x (was {base_width}, now {shaped_width})",
            s.surge
        );
        // Total span shrinks by the time the compression saved.
        let saved = (0.6 * span - 0.4 * span) * (1.0 - 1.0 / s.surge);
        let new_span = shaped.last().unwrap().arrival_s;
        assert!((span - saved - new_span).abs() < 1e-6);
    }

    #[test]
    fn mass_interruption_fails_a_block_simultaneously() {
        let s = Scenario::mass_interruption();
        let events = s.membership_events(4, 90_000.0);
        assert_eq!(events.len(), 2, "half of 4 alive nodes fail");
        for ev in &events {
            assert_eq!(ev.change, MembershipChange::Fail);
            assert_eq!(ev.at_s, 30_000.0, "all failures land at the same instant");
        }
        assert_eq!(
            events.iter().map(|e| e.node).collect::<Vec<_>>(),
            vec![2, 3],
            "the highest-indexed alive nodes are reclaimed"
        );
        // Never kill the whole fleet, even at frac 1.0.
        let mut total = Scenario::mass_interruption();
        total.interruption_frac = 1.0;
        assert_eq!(total.membership_events(3, 900.0).len(), 2, "one node always survives");
        assert!(total.membership_events(1, 900.0).is_empty());
    }

    #[test]
    fn straggler_slows_exactly_node_zero() {
        let s = Scenario::straggler();
        let m = s.service_multipliers(4);
        assert_eq!(m, vec![4.0, 1.0, 1.0, 1.0]);
        assert!(Scenario::diurnal().service_multipliers(4).is_empty());
    }

    #[test]
    fn names_round_trip() {
        for scenario in Scenario::all() {
            assert_eq!(Scenario::by_name(scenario.name()), Some(scenario));
        }
        assert_eq!(Scenario::by_name("nope"), None);
    }
}
