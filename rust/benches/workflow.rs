//! Bench: full workflow throughput — one task through N rounds (the unit the
//! coordinator parallelizes), plus the agent calls individually.

#![allow(clippy::disallowed_methods)]

use cudaforge::agents::profiles::O3;
use cudaforge::agents::{Coder, Judge, MetricMode};
use cudaforge::gpu::RTX6000_ADA;
use cudaforge::kernel::KernelConfig;
use cudaforge::sim::{ncu, simulate, SimParams};
use cudaforge::tasks::{by_id, dstar};
use cudaforge::util::bench::{bench, black_box};
use cudaforge::util::rng::Rng;
use cudaforge::workflow::{run_task, NoOracle, Strategy, WorkflowConfig};

fn main() {
    let task = by_id("L2-51").unwrap();
    let gpu = &RTX6000_ADA;
    let wf = WorkflowConfig::cudaforge(gpu, 7);

    bench("workflow::run_task (CudaForge, N=10)", 200_000, || {
        black_box(run_task(&wf, &task, &NoOracle));
    });

    let wf1 = wf.clone().with_strategy(Strategy::OneShot);
    bench("workflow::run_task (one-shot)", 500_000, || {
        black_box(run_task(&wf1, &task, &NoOracle));
    });

    let coder = Coder::new(O3);
    let mut rng = Rng::new(3);
    bench("agents::coder.initial", 1_000_000, || {
        black_box(coder.initial(&task, gpu, &mut rng));
    });

    let judge = Judge::new(O3, MetricMode::Subset);
    let mut cfg = KernelConfig::naive();
    cfg.legalize(gpu);
    let out = simulate(gpu, &task, &cfg, &SimParams::default(), 1.0);
    let metrics = ncu::profile(gpu, &task, &cfg, &out, &mut rng);
    bench("agents::judge.optimization", 500_000, || {
        black_box(judge.optimization(&task, gpu, &cfg, &metrics, &mut rng));
    });

    let set = dstar();
    bench("coordinator: D* suite serial (25 tasks)", 5_000, || {
        for t in &set {
            black_box(run_task(&wf, t, &NoOracle));
        }
    });
}
