//! Bench: the PJRT runtime path — artifact compile (cold) and execute (hot),
//! the real-numerics cost the oracle amortizes by verifying once.
//!
//! Skips (prints a notice) when `artifacts/` is missing.

#![allow(clippy::disallowed_methods)]

#[cfg(not(feature = "pjrt"))]
fn main() {
    println!("runtime_pjrt: built without the `pjrt` feature; skipping");
}

#[cfg(feature = "pjrt")]
fn main() {
    use cudaforge::runtime::Engine;
    use cudaforge::util::bench::{bench, black_box};

    let mut engine = match Engine::new("artifacts") {
        Ok(e) => e,
        Err(_) => {
            println!("runtime_pjrt: artifacts missing — run `make artifacts` first; skipping");
            return;
        }
    };

    for name in ["ew_chain_fused", "softmax_online", "matmul_tiled", "mini_model_pallas"] {
        let entry = engine.manifest().by_name(name).unwrap().clone();
        let inputs = engine.gen_inputs(&entry, 42).unwrap();
        // cold compile happens on first execute; measure the hot path after.
        engine.execute(name, &inputs).unwrap();
        bench(&format!("pjrt::execute {name}"), 50_000, || {
            black_box(engine.execute(name, &inputs).unwrap());
        });
    }

    let entry = engine.manifest().by_name("cross_entropy_lane_reduce").unwrap().clone();
    bench("pjrt::gen_inputs (cross_entropy)", 500_000, || {
        black_box(engine.gen_inputs(&entry, 7).unwrap());
    });

    bench("pjrt::check_against_ref (cross_entropy)", 20_000, || {
        black_box(engine.check_against_ref("cross_entropy_lane_reduce", 7).unwrap());
    });
}
