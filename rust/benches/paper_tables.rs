//! Bench-style end-to-end timing of the paper-table generators: how long
//! each experiment takes to regenerate (meso-benchmarks backing `make paper`).
//! These run each experiment ONCE in quick mode and report wall time — the
//! full-suite versions run via `cudaforge bench --exp all` (`make paper`).

use std::time::Instant;

use cudaforge::report::{self, Ctx};
use cudaforge::workflow::NoOracle;

fn main() {
    let ctx = Ctx {
        results_dir: "results/bench".into(),
        ..Ctx::default()
    };
    for exp in [
        "table1", "table2", "table3", "table4", "table5", "fig4", "fig5", "fig6",
        "fig7", "fig8", "fig9", "table6", "table8",
    ] {
        let t = Instant::now();
        report::run_experiment(&ctx, exp, &NoOracle, true);
        println!(">> experiment {exp}: {:.2}s\n", t.elapsed().as_secs_f64());
    }
}
