//! Bench: the offline metric-selection pipeline (Algorithms 1-2) and its
//! statistical primitives.

#![allow(clippy::disallowed_methods)]

use cudaforge::gpu::RTX6000_ADA;
use cudaforge::metrics::{remove_aliases, sample_kernels, select_metrics, top20};
use cudaforge::sim::SimParams;
use cudaforge::tasks::by_id;
use cudaforge::util::bench::{bench, black_box};
use cudaforge::util::rng::Rng;
use cudaforge::util::stats::pearson;

fn main() {
    let params = SimParams::default();
    let task = by_id("L1-1").unwrap();
    let mut rng = Rng::new(5);

    let kernels = sample_kernels(&RTX6000_ADA, &task, &params, 100, &mut rng);

    bench("metrics::sample_kernels (100 iters)", 10_000, || {
        let mut r = Rng::new(5);
        black_box(sample_kernels(&RTX6000_ADA, &task, &params, 100, &mut r));
    });

    bench("metrics::remove_aliases (64x64 pearson)", 100_000, || {
        black_box(remove_aliases(&kernels));
    });

    bench("metrics::top20 (one task)", 100_000, || {
        black_box(top20(&task, &kernels));
    });

    bench("metrics::select_metrics (8 tasks, 100 iters)", 1_000, || {
        black_box(select_metrics(&RTX6000_ADA, &params, 100, 2025));
    });

    let xs: Vec<f64> = (0..10_000).map(|i| (i as f64).sin()).collect();
    let ys: Vec<f64> = (0..10_000).map(|i| (i as f64).cos()).collect();
    bench("stats::pearson (10k points)", 1_000_000, || {
        black_box(pearson(&xs, &ys));
    });
}
