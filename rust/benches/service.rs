//! Bench: the service-layer hot paths — fingerprinting, cache lookups under
//! LRU churn, single-flight joins on the fleet, the event-driven fleet
//! simulator itself, and an end-to-end traffic replay. The admission path
//! (fingerprint + cache probe + fleet advance) runs once per request at
//! serving time, so it must stay far below the microsecond regime. The
//! final pair of replays shows the `window` knob is host-side batching
//! only: both run the identical event-driven simulation.
//!
//! A trace-size sweep reports end-to-end replay throughput in requests/s at
//! several sizes; set `CUDAFORGE_BENCH_JSON=<path>` to also emit the whole
//! series as JSON (`BENCH_service.json` at the repo root is the committed
//! reference run) and `CUDAFORGE_BENCH_FAST=1` for a CI-speed smoke pass.

use cudaforge::agents::profiles::O3;
use cudaforge::gpu::RTX6000_ADA;
use cudaforge::kernel::KernelConfig;
use cudaforge::service::cache::{CacheEntry, ResultCache};
use cudaforge::service::fingerprint::{of_request, Fingerprint};
use cudaforge::service::pool::{
    DispatchSnapshot, FleetHooks, FleetSim, MemberList, SimCompletion, SimFlight,
};
use cudaforge::service::queue::Priority;
use cudaforge::service::traffic::{generate, TrafficConfig};
use cudaforge::service::{KernelService, ServiceConfig};
use cudaforge::tasks;
use cudaforge::util::bench::{black_box, BenchSet, CountingAlloc};
use cudaforge::workflow::{NoOracle, Strategy};

// Count every allocation so the JSON series carries `total_allocations`
// next to throughput (see `util::bench::CountingAlloc`).
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn entry(fp: u64) -> CacheEntry {
    CacheEntry {
        fingerprint: Fingerprint(fp),
        task_id: format!("L1-{}", fp % 100 + 1),
        gpu_key: "rtx6000".to_string(),
        strategy: "CudaForge".to_string(),
        coder: "OpenAI-o3".to_string(),
        judge: "OpenAI-o3".to_string(),
        best_speedup: 1.5,
        best_config: KernelConfig::naive(),
        api_usd: 0.30,
        cold_api_usd: 0.30,
        wall_s: 1590.0,
        rounds_to_best: 6,
    }
}

/// Constant-service-time hooks: the fleet mechanics without workflow cost.
struct Fixed(f64);

impl FleetHooks for Fixed {
    fn on_start(&mut self, _f: &SimFlight, _start_s: f64, _fair: DispatchSnapshot) -> f64 {
        self.0
    }
    fn on_complete(&mut self, _f: &SimFlight, _done: SimCompletion) {}
}

fn main() {
    let suite = tasks::kernelbench();
    let task = &suite[0];
    let mut set = BenchSet::new("service");

    set.run("service::fingerprint::of_request", 2_000_000, 1.0, || {
        black_box(of_request(task, &RTX6000_ADA, &O3, &O3, Strategy::CudaForge, 10));
    });

    let mut cache = ResultCache::new(512);
    for i in 0..512u64 {
        cache.insert(entry(i));
    }
    let mut i = 0u64;
    set.run("service::cache get+insert under LRU churn", 1_000_000, 1.0, || {
        black_box(cache.get(Fingerprint(i % 700)));
        if i % 7 == 0 {
            cache.insert(entry(i % 900));
        }
        i += 1;
    });

    let mut seq = 0u64;
    set.run("service::fleet submit+join (window of 32, heavy dedup)", 200_000, 32.0, || {
        let mut fleet = FleetSim::new(4);
        let mut hooks = Fixed(900.0);
        for k in 0..32u64 {
            let fp = Fingerprint(k % 11); // heavy dedup: most arrivals join
            if !fleet.join_waiting(fp, seq, k as f64, Priority::Standard) {
                fleet.submit(SimFlight {
                    fingerprint: fp,
                    priority: Priority::Standard,
                    leader_seq: seq,
                    tenant: 0,
                    arrival_s: k as f64,
                    members: MemberList::one(seq, k as f64),
                });
            }
            seq += 1;
        }
        fleet.advance(f64::INFINITY, &mut hooks);
        black_box(fleet.flights_served());
    });

    let mut sim_seq = 0u64;
    set.run("service::fleet submit+advance (16 flights, 4 workers)", 100_000, 16.0, || {
        let mut fleet = FleetSim::new(4);
        let mut hooks = Fixed(900.0);
        for k in 0..16u64 {
            fleet.submit(SimFlight {
                fingerprint: Fingerprint(sim_seq ^ k),
                priority: Priority::Standard,
                leader_seq: sim_seq + k,
                tenant: 0,
                arrival_s: k as f64 * 3.0,
                members: MemberList::one(sim_seq + k, k as f64 * 3.0),
            });
        }
        fleet.advance(f64::INFINITY, &mut hooks);
        black_box(fleet.flights_served());
        sim_seq += 16;
    });

    set.run("service::replay 200 Zipf requests (e2e)", 500, 200.0, || {
        let trace = generate(
            suite.len(),
            &TrafficConfig { requests: 200, ..TrafficConfig::default() },
        );
        let mut svc = KernelService::new(ServiceConfig {
            threads: 1,
            window: 16,
            ..ServiceConfig::default()
        });
        black_box(svc.replay(&trace, &suite, &NoOracle));
    });

    // The window knob batches host work only; the simulation is identical.
    for window in [1usize, 64] {
        let name = format!("service::replay 200 Zipf requests (window {window})");
        set.run(&name, 200, 200.0, || {
            let trace = generate(
                suite.len(),
                &TrafficConfig { requests: 200, ..TrafficConfig::default() },
            );
            let mut svc = KernelService::new(ServiceConfig {
                threads: 1,
                window,
                ..ServiceConfig::default()
            });
            black_box(svc.replay(&trace, &suite, &NoOracle));
        });
    }

    // Throughput sweep: how replay cost scales with trace size. The trace
    // is generated outside the timed closure so the figure is the replay
    // itself, reported in requests/s via `units_per_iter`. The large-trace
    // entries (100k / 1M requests) exist for the committed reference JSON
    // and are skipped in fast mode so the CI smoke pass stays in seconds.
    let fast = matches!(std::env::var("CUDAFORGE_BENCH_FAST"), Ok(v) if !v.is_empty() && v != "0");
    let mut sizes = vec![200usize, 1000, 4000];
    if !fast {
        sizes.extend([100_000, 1_000_000]);
    }
    for requests in sizes {
        let trace = generate(
            suite.len(),
            &TrafficConfig { requests, ..TrafficConfig::default() },
        );
        let name = format!("service::replay throughput ({requests} reqs)");
        let iters = (200_000 / requests.max(1)) as u64;
        set.run(&name, iters.max(10), requests as f64, || {
            let mut svc = KernelService::new(ServiceConfig {
                threads: 1,
                window: 16,
                ..ServiceConfig::default()
            });
            black_box(svc.replay(&trace, &suite, &NoOracle));
        });
    }

    set.finish();
}
