//! Bench: the cluster layer's hot paths — rendezvous routing (once per
//! request at admission time, so it must stay in the tens-of-nanoseconds
//! regime), the fair-share quota derivation, an end-to-end sharded replay
//! (the global event loop interleaving all node fleets in timestamp
//! order), the same replay through a fail + rejoin membership cycle (the
//! planned-rebalance path), and a shard-aware snapshot save/restore round
//! trip.
//!
//! A node-count sweep reports sharded-replay throughput in requests/s at
//! several fleet sizes; set `CUDAFORGE_BENCH_JSON=<path>` to also emit the
//! whole series as JSON (`BENCH_cluster.json` at the repo root is the
//! committed reference run) and `CUDAFORGE_BENCH_FAST=1` for a CI-speed
//! smoke pass.

use cudaforge::cluster::{
    fair_share_quotas, ClusterConfig, ClusterService, MembershipEvent, Router, TenantSpec,
};
use cudaforge::service::fingerprint::Fingerprint;
use cudaforge::service::traffic::{generate, TrafficConfig};
use cudaforge::service::ServiceConfig;
use cudaforge::tasks;
use cudaforge::util::bench::{black_box, BenchSet, CountingAlloc};
use cudaforge::workflow::NoOracle;

// Count every allocation so the JSON series carries `total_allocations`
// next to throughput (see `util::bench::CountingAlloc`).
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn main() {
    let mut set = BenchSet::new("cluster");

    let router = Router::new(8);
    let alive = vec![true; 8];
    let mut k = 0u64;
    set.run("cluster::router route (8 nodes)", 2_000_000, 1.0, || {
        let fp = Fingerprint(k.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        black_box(router.route(fp, &alive));
        k += 1;
    });

    let mut degraded = vec![true; 8];
    degraded[3] = false;
    let mut j = 0u64;
    set.run("cluster::router route (8 nodes, 1 dead)", 2_000_000, 1.0, || {
        let fp = Fingerprint(j.wrapping_mul(0x2545_F491_4F6C_DD1D));
        black_box(router.route(fp, &degraded));
        j += 1;
    });

    let tenants: Vec<TenantSpec> = (0..16)
        .map(|i| TenantSpec::new(format!("t{i}"), 1.0 + i as f64))
        .collect();
    set.run("cluster::fair_share_quotas (16 tenants)", 1_000_000, 1.0, || {
        black_box(fair_share_quotas(64, &tenants));
    });

    let suite = tasks::kernelbench();
    let trace = generate(
        suite.len(),
        &TrafficConfig {
            requests: 200,
            tenant_mix: vec![("alpha".to_string(), 3.0), ("beta".to_string(), 1.0)],
            ..TrafficConfig::default()
        },
    );
    let base = || ClusterConfig {
        nodes: 4,
        tenants: vec![TenantSpec::new("alpha", 3.0), TenantSpec::new("beta", 1.0)],
        tenant_quotas: true,
        service: ServiceConfig {
            threads: 1,
            window: 16,
            sim_workers: 2,
            queue_depth: 16,
            ..ServiceConfig::default()
        },
        ..ClusterConfig::default()
    };
    set.run("cluster::replay 200 Zipf requests over 4 nodes (e2e)", 200, 200.0, || {
        let mut svc = ClusterService::new(base());
        black_box(svc.replay(&trace, &suite, &NoOracle));
    });

    // The elastic-membership path: a node dies a third of the way in and
    // rejoins (empty) two thirds in — the replay pays shard loss, re-miss
    // re-runs, and the join's planned-rebalance refills.
    let fail_at = trace[trace.len() / 3].arrival_s;
    let rejoin_at = trace[2 * trace.len() / 3].arrival_s;
    set.run("cluster::replay with fail + rejoin (planned rebalance)", 200, 200.0, || {
        let mut cfg = base();
        cfg.events =
            vec![MembershipEvent::fail(1, fail_at), MembershipEvent::join(1, rejoin_at)];
        let mut svc = ClusterService::new(cfg);
        black_box(svc.replay(&trace, &suite, &NoOracle));
    });

    // Throughput sweep: the same 200-request trace replayed over growing
    // fleets — the event heap keeps per-event cost at O(log events) rather
    // than O(nodes), and the figure is reported in requests/s via
    // `units_per_iter`. The 16- and 64-node points exist to show that
    // flatness in the committed reference JSON.
    for nodes in [1usize, 4, 8, 16, 64] {
        let name = format!("cluster::replay throughput (200 reqs, {nodes} nodes)");
        set.run(&name, 200, 200.0, || {
            let mut cfg = base();
            cfg.nodes = nodes;
            let mut svc = ClusterService::new(cfg);
            black_box(svc.replay(&trace, &suite, &NoOracle));
        });
    }

    // Large-trace entry: 100k requests sharded over 16 nodes. Exists for
    // the committed reference JSON; skipped in fast mode so the CI smoke
    // pass stays in seconds.
    let fast = matches!(std::env::var("CUDAFORGE_BENCH_FAST"), Ok(v) if !v.is_empty() && v != "0");
    if !fast {
        let big = generate(
            suite.len(),
            &TrafficConfig {
                requests: 100_000,
                tenant_mix: vec![("alpha".to_string(), 3.0), ("beta".to_string(), 1.0)],
                ..TrafficConfig::default()
            },
        );
        set.run("cluster::replay throughput (100000 reqs, 16 nodes)", 20, 100_000.0, || {
            let mut cfg = base();
            cfg.nodes = 16;
            let mut svc = ClusterService::new(cfg);
            black_box(svc.replay(&big, &suite, &NoOracle));
        });
    }

    // Shard-aware snapshot round trip: manifest + N shard files + the
    // cold-cost registry, written and cross-checked back in.
    let mut warm = ClusterService::new(base());
    warm.replay(&trace, &suite, &NoOracle);
    let dir = std::env::temp_dir().join("cudaforge_cluster_bench_snapshot");
    let _ = std::fs::remove_dir_all(&dir);
    set.run("cluster::snapshot save + restore (4 shards)", 50, 1.0, || {
        warm.snapshot(&dir).expect("snapshot");
        black_box(ClusterService::restore(base(), &dir).expect("restore"));
    });

    set.finish();
}
