//! Bench: the GPU simulator + NCU emission hot path (called ~10^4-10^5 times
//! per suite run — the L3 §Perf target).

use cudaforge::gpu::RTX6000_ADA;
use cudaforge::kernel::KernelConfig;
use cudaforge::sim::{baseline_time, ncu, simulate, SimParams};
use cudaforge::tasks::kernelbench;
use cudaforge::util::bench::{bench, black_box};
use cudaforge::util::rng::Rng;

fn main() {
    let tasks = kernelbench();
    let params = SimParams::default();
    let gpu = &RTX6000_ADA;
    let mut cfg = KernelConfig::naive();
    cfg.use_smem = true;
    cfg.coalesced = true;
    cfg.tile_m = 64;
    cfg.tile_n = 64;
    cfg.tile_k = 32;
    cfg.syncs_per_tile = 2;
    cfg.legalize(gpu);
    let task = &tasks[0];

    bench("sim::simulate (single eval)", 2_000_000, || {
        black_box(simulate(gpu, task, &cfg, &params, 1.0));
    });

    let out = simulate(gpu, task, &cfg, &params, 1.0);
    let mut rng = Rng::new(1);
    bench("sim::ncu::profile (64 metrics)", 1_000_000, || {
        black_box(ncu::profile(gpu, task, &cfg, &out, &mut rng));
    });

    bench("sim::baseline_time", 1_000_000, || {
        black_box(baseline_time(gpu, task, &params));
    });

    bench("sim::simulate x250 tasks", 20_000, || {
        for t in &tasks {
            black_box(simulate(gpu, t, &cfg, &params, 1.0));
        }
    });

    bench("tasks::kernelbench (suite gen)", 20_000, || {
        black_box(kernelbench());
    });
}
