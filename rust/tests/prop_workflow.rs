//! Property tests over the coordinator/workflow invariants (the proptest
//! role, via util::prop): run randomized workflows and check the paper's
//! accounting identities hold for every trace.

use cudaforge::gpu;
use cudaforge::tasks::kernelbench;
use cudaforge::util::prop::{check_with, ensure};
use cudaforge::util::rng::Rng;
use cudaforge::workflow::{run_task, NoOracle, Strategy, WorkflowConfig};

const STRATEGIES: [Strategy; 8] = [
    Strategy::OneShot,
    Strategy::SelfRefine,
    Strategy::CorrectionOnly,
    Strategy::OptimizationOnly,
    Strategy::CudaForge,
    Strategy::CudaForgeFullMetrics,
    Strategy::Kevin,
    Strategy::AgenticBaseline,
];

fn random_wf(rng: &mut Rng) -> WorkflowConfig {
    let gpu = gpu::ALL[rng.below(gpu::ALL.len())];
    WorkflowConfig::cudaforge(gpu, rng.next_u64())
        .with_strategy(STRATEGIES[rng.below(STRATEGIES.len())])
        .with_rounds(rng.range_usize(1, 12))
}

#[test]
fn prop_task_result_invariants() {
    let tasks = kernelbench();
    check_with("task-result-invariants", 0xF00D, 60, |rng| {
        let task = &tasks[rng.below(tasks.len())];
        let wf = random_wf(rng);
        let r = run_task(&wf, task, &NoOracle);
        // Correctness flag consistent with the best config.
        ensure(r.correct == r.best_config.is_some(), "correct <-> best_config")?;
        ensure(
            (r.correct && r.best_speedup > 0.0) || (!r.correct && r.best_speedup == 0.0),
            "speedup consistent with correctness",
        )?;
        // Best speedup covers the per-round measured speedups. For the
        // iterative strategies it is exactly the max over the logged rounds;
        // Kevin/agentic log only one trajectory (resp. the round winner), so
        // their best may exceed the logged max but never fall below it.
        let max_round = r.rounds.iter().filter_map(|x| x.speedup).fold(0.0f64, f64::max);
        match wf.strategy {
            Strategy::Kevin | Strategy::AgenticBaseline => ensure(
                r.best_speedup >= max_round - 1e-9,
                format!("best {} >= logged max {}", r.best_speedup, max_round),
            )?,
            _ => ensure(
                (r.best_speedup - max_round).abs() < 1e-9,
                format!("best {} == max round {}", r.best_speedup, max_round),
            )?,
        }
        // Rounds marked correct must carry a speedup and vice versa.
        for round in &r.rounds {
            ensure(round.correct == round.speedup.is_some(), "round correct <-> speedup")?;
            ensure(
                round.speedup.map(|s| s.is_finite() && s > 0.0).unwrap_or(true),
                "speedup finite",
            )?;
            // compile failures can never be correct
            ensure(round.compiled || !round.correct, "uncompiled can't be correct")?;
        }
        // Ledger sanity.
        ensure(r.ledger.api_usd >= 0.0 && r.ledger.wall_s > 0.0, "ledger positive")?;
        ensure(r.ledger.agent_calls >= 1, "at least the initial generation")?;
        ensure(
            r.ledger.tokens_in > 0.0 || wf.strategy == Strategy::Kevin,
            "tokens accounted",
        )?;
        Ok(())
    });
}

#[test]
fn prop_mode_sequencing_follows_the_paper_loop() {
    // After a failing round the next round is a correction; after a passing
    // round the next is an optimization (Fig. 2's two feedback arrows).
    let tasks = kernelbench();
    check_with("mode-sequencing", 0xAB1E, 60, |rng| {
        let task = &tasks[rng.below(tasks.len())];
        let wf = WorkflowConfig::cudaforge(
            gpu::ALL[rng.below(gpu::ALL.len())],
            rng.next_u64(),
        )
        .with_rounds(rng.range_usize(2, 12));
        let r = run_task(&wf, task, &NoOracle);
        for w in r.rounds.windows(2) {
            let expected = if w[0].correct { "optimization" } else { "correction" };
            ensure(
                w[1].mode == expected,
                format!(
                    "round {} after correct={} was {}",
                    w[1].round, w[0].correct, w[1].mode
                ),
            )?;
        }
        ensure(r.rounds[0].mode == "initial", "first round is the initial generation")?;
        Ok(())
    });
}

#[test]
fn prop_feedback_wire_format_always_parses() {
    // Every non-final round's feedback must be valid JSON that round-trips
    // through the Appendix-A schema.
    use cudaforge::agents::Feedback;
    use cudaforge::util::json::Json;
    let tasks = kernelbench();
    check_with("feedback-wire-format", 0x1CE, 40, |rng| {
        let task = &tasks[rng.below(tasks.len())];
        let wf = random_wf(rng);
        let r = run_task(&wf, task, &NoOracle);
        for round in &r.rounds {
            if round.feedback_json.is_empty() {
                continue;
            }
            let v = Json::parse(&round.feedback_json)
                .map_err(|e| format!("invalid JSON: {e}"))?;
            ensure(Feedback::from_json(&v).is_some(), "schema parse")?;
        }
        Ok(())
    });
}

#[test]
fn prop_more_rounds_never_worse_same_seed() {
    // With the same seed, raising N extends the same trajectory, so the
    // best-of selection can only improve (monotone test-time scaling).
    let tasks = kernelbench();
    check_with("rounds-monotone", 0x5EED, 30, |rng| {
        let task = &tasks[rng.below(tasks.len())];
        let seed = rng.next_u64();
        let gpu = &gpu::RTX6000_ADA;
        let small = run_task(
            &WorkflowConfig::cudaforge(gpu, seed).with_rounds(4),
            task,
            &NoOracle,
        );
        let large = run_task(
            &WorkflowConfig::cudaforge(gpu, seed).with_rounds(12),
            task,
            &NoOracle,
        );
        ensure(
            large.best_speedup >= small.best_speedup * 0.999 - 1e-9,
            format!("N=12 {} vs N=4 {}", large.best_speedup, small.best_speedup),
        )?;
        Ok(())
    });
}

#[test]
fn prop_cost_scales_with_rounds() {
    let tasks = kernelbench();
    check_with("cost-scales", 0xC057, 30, |rng| {
        let task = &tasks[rng.below(tasks.len())];
        let seed = rng.next_u64();
        let gpu = &gpu::RTX6000_ADA;
        let a = run_task(&WorkflowConfig::cudaforge(gpu, seed).with_rounds(2), task, &NoOracle);
        let b = run_task(&WorkflowConfig::cudaforge(gpu, seed).with_rounds(10), task, &NoOracle);
        ensure(b.ledger.api_usd > a.ledger.api_usd, "more rounds, more spend")?;
        ensure(b.ledger.wall_s > a.ledger.wall_s, "more rounds, more time")?;
        Ok(())
    });
}
