//! Integration: closed-loop autoscaling over the sharded cluster replay.
//!
//! The contracts pinned here are the subsystem's acceptance criteria:
//!
//! 1. A [`StaticPolicy`] run with the steady (identity) scenario is
//!    **bit-identical** to a plain `ClusterService::replay` — the
//!    autoscaling loop's decision ticks are pure observations.
//! 2. The threshold and target-tracking policies each produce at least one
//!    join *and* one fail on the diurnal and flash-crowd scenarios, every
//!    action is priced by a matching entry in `ClusterReport::rebalances`,
//!    joins land exactly one provisioning delay after their decision — and
//!    the whole report (actions included) is bit-identical across OS
//!    `threads` 1/2/8 and `window` sizes.
//! 3. Scenario-scripted membership events (the mass interruption) flow
//!    through the same validated, priced machinery as policy decisions.
//!
//! The fleet here is deliberately tiny and slow-ticking: 4 tasks on one
//! GPU so the cacheable key population is 4, one simulated worker per
//! node, 6 node slots of which 4 start alive, an hourly decision tick.
//! With those numbers the first tick's window provably contains a cold
//! workflow start (hundreds of busy-seconds against a 0.02 utilization
//! high-water mark → a join), and once all four keys are cached there are
//! provably all-hit windows (0.0 busy-seconds against a 0.01 low-water
//! mark, empty queues → a fail). The preconditions those arguments rest on
//! are asserted against the generated trace, so a parameter drift fails
//! loudly here instead of flaking downstream.

#![allow(clippy::disallowed_methods)]

use cudaforge::cluster::autoscale::{
    AutoscaleConfig, AutoscalePolicy, ScheduledAction, StaticPolicy, TargetTrackingPolicy,
    ThresholdPolicy,
};
use cudaforge::cluster::{
    AutoscaleRun, ClusterConfig, ClusterReport, ClusterService, MembershipChange,
    RebalanceKind, Scenario,
};
use cudaforge::service::traffic::{generate, TrafficConfig, TrafficRequest};
use cudaforge::service::ServiceConfig;
use cudaforge::tasks::{self, TaskSpec};
use cudaforge::workflow::NoOracle;
use std::collections::BTreeMap;

/// Node slots in the cluster config (the autoscaler's provisioning pool).
const SLOTS: usize = 6;
/// Slots alive at replay start; the rest are dead headroom.
const START_ALIVE: usize = 4;
const TICK_S: f64 = 3600.0;
const PROVISION_DELAY_S: f64 = 600.0;

fn small_suite() -> Vec<TaskSpec> {
    tasks::kernelbench().into_iter().take(4).collect()
}

fn base_trace(priority_mix: [f64; 3]) -> Vec<TrafficRequest> {
    generate(
        4,
        &TrafficConfig {
            requests: 600,
            mean_interarrival_s: 90.0,
            gpu_mix: vec![("rtx6000", 1.0)],
            priority_mix,
            ..TrafficConfig::default()
        },
    )
}

fn cluster_config(threads: usize, window: usize, scenario: &Scenario) -> ClusterConfig {
    ClusterConfig {
        nodes: SLOTS,
        initial_dead: (START_ALIVE..SLOTS).collect(),
        node_service_multipliers: scenario.service_multipliers(SLOTS),
        service: ServiceConfig {
            threads,
            window,
            sim_workers: 1,
            seed: 7,
            ..ServiceConfig::default()
        },
        ..ClusterConfig::default()
    }
}

fn autoscale_cfg() -> AutoscaleConfig {
    AutoscaleConfig {
        tick_s: TICK_S,
        provision_delay_s: PROVISION_DELAY_S,
        min_nodes: 1,
        max_nodes: SLOTS,
    }
}

/// The preconditions the guaranteed-join / guaranteed-fail arguments rest
/// on (see the module doc). Asserted per shaped trace so a tuning drift in
/// the generator or the shapers fails here, with a name, not downstream.
fn assert_trace_preconditions(trace: &[TrafficRequest], name: &str) {
    assert!(
        trace[0].arrival_s < TICK_S,
        "{name}: the first (necessarily cold) arrival must land inside the first tick window"
    );
    assert!(
        trace.iter().all(|r| r.gpu.key == "rtx6000"),
        "{name}: a single-GPU mix keeps the key population at 4"
    );
    let mut first_seen: BTreeMap<usize, f64> = BTreeMap::new();
    for req in trace {
        first_seen.entry(req.task_index).or_insert(req.arrival_s);
    }
    assert_eq!(first_seen.len(), 4, "{name}: all four tasks appear in the trace");
    let last_new = first_seen.values().fold(0.0f64, |a, b| a.max(*b));
    let span = trace.last().unwrap().arrival_s;
    // Cold workflows run well under ~1600 simulated seconds each; two of
    // those (service + possible same-node queueing) past the last novel
    // key, plus two whole tick windows, must still fit before the trace
    // ends — that guarantees an all-hit, zero-busy window for the
    // scale-down half of each policy.
    assert!(
        last_new + 2.0 * 1600.0 + 2.0 * TICK_S < span,
        "{name}: an all-hit tick window must exist after the cold population completes \
         (last novel key at {last_new:.0}s, span {span:.0}s)"
    );
}

/// Every policy action must be priced: a rebalance entry with the matching
/// kind, node, and landing instant. Joins land exactly one provisioning
/// delay after their decision tick; fails land at the tick itself.
fn assert_actions_priced(actions: &[ScheduledAction], report: &ClusterReport, name: &str) {
    for action in actions {
        let kind = match action.change {
            MembershipChange::Fail => RebalanceKind::NodeFailure,
            MembershipChange::Join => RebalanceKind::NodeJoin,
        };
        assert!(
            report.rebalances.iter().any(|rb| rb.kind == kind
                && rb.node == action.node
                && rb.at_s == action.at_s),
            "{name}: action {action:?} has no matching rebalance entry"
        );
        match action.change {
            MembershipChange::Join => assert_eq!(
                action.at_s,
                action.decided_at_s + PROVISION_DELAY_S,
                "{name}: joins land one provisioning delay after the decision"
            ),
            MembershipChange::Fail => assert_eq!(
                action.at_s, action.decided_at_s,
                "{name}: fails land at the decision instant"
            ),
        }
    }
}

fn make_policy(policy_name: &str) -> Box<dyn AutoscalePolicy> {
    match policy_name {
        // Thresholds sized to the tiny fleet: one cold workflow start in a
        // tick window clears 0.02 mean utilization; an all-hit window is
        // exactly 0.0. The huge backlog threshold keeps the utilization
        // signal the only scale-up trigger, so the test argument stays
        // one-dimensional.
        "threshold" => Box::new(ThresholdPolicy::new(0.02, 0.01, 1e9, 0)),
        // Defend perfect attainment: any window completing a cold
        // interactive request (minutes of latency against a 120 s SLO)
        // scales up; all-hit idle windows (attainment 1.0, utilization
        // 0.0) scale down.
        "target-tracking" => Box::new(TargetTrackingPolicy::new(1.0, 0.01, 0)),
        other => panic!("unknown test policy {other}"),
    }
}

#[allow(clippy::type_complexity)]
fn run_autoscaled(
    policy_name: &str,
    scenario: &Scenario,
    trace: &[TrafficRequest],
    suite: &[TaskSpec],
    threads: usize,
    window: usize,
) -> (ClusterReport, Vec<ScheduledAction>, usize) {
    let mut run = AutoscaleRun::new(make_policy(policy_name), autoscale_cfg());
    let mut svc = ClusterService::new(cluster_config(threads, window, scenario));
    let report = svc.replay_autoscaled(trace, suite, &NoOracle, &mut run);
    let actions = run.actions.clone();
    (report, actions, run.ticks)
}

#[test]
fn static_policy_with_no_shaper_reproduces_the_plain_cluster_replay() {
    let suite = small_suite();
    let trace = base_trace([0.2, 0.6, 0.2]);
    let scenario = Scenario::steady();
    let mut plain_svc = ClusterService::new(cluster_config(2, 16, &scenario));
    let plain = plain_svc.replay(&trace, &suite, &NoOracle);

    for (threads, window) in [(1usize, 1usize), (2, 16), (8, 64)] {
        let mut run = AutoscaleRun::new(Box::new(StaticPolicy), autoscale_cfg());
        let mut svc = ClusterService::new(cluster_config(threads, window, &scenario));
        let report = svc.replay_autoscaled(&trace, &suite, &NoOracle, &mut run);
        assert_eq!(
            report, plain,
            "threads {threads} window {window}: static autoscaling must be bit-identical \
             to the plain replay"
        );
        assert!(run.actions.is_empty(), "the static policy never acts");
        assert!(run.ticks > 0, "decision ticks actually fired");
    }
}

#[test]
fn threshold_policy_joins_and_fails_on_shaped_traffic_bit_identically() {
    let suite = small_suite();
    for scenario in [Scenario::diurnal(), Scenario::flash_crowd()] {
        let mut trace = base_trace([0.2, 0.6, 0.2]);
        scenario.shape_arrivals(&mut trace);
        assert_trace_preconditions(&trace, scenario.name());

        let baseline = run_autoscaled("threshold", &scenario, &trace, &suite, 1, 1);
        let (report, actions, ticks) = &baseline;
        assert!(*ticks >= 10, "{}: the trace spans many decision ticks", scenario.name());
        let joins =
            actions.iter().filter(|a| a.change == MembershipChange::Join).count();
        let fails =
            actions.iter().filter(|a| a.change == MembershipChange::Fail).count();
        assert!(joins >= 1, "{}: the hot first window forces a join", scenario.name());
        assert!(fails >= 1, "{}: an all-hit window forces a fail", scenario.name());
        assert_actions_priced(actions, report, scenario.name());

        for (threads, window) in [(2usize, 16usize), (8, 64)] {
            let other = run_autoscaled("threshold", &scenario, &trace, &suite, threads, window);
            assert_eq!(
                other, baseline,
                "{}: threads {threads} window {window} must be bit-identical",
                scenario.name()
            );
        }
    }
}

#[test]
fn target_tracking_policy_joins_and_fails_on_shaped_traffic_bit_identically() {
    let suite = small_suite();
    for scenario in [Scenario::diurnal(), Scenario::flash_crowd()] {
        // All-interactive traffic: a cold workflow (minutes of simulated
        // latency) can never meet the 120 s interactive SLO, so any window
        // completing one drops attainment below the 1.0 target.
        let mut trace = base_trace([1.0, 0.0, 0.0]);
        scenario.shape_arrivals(&mut trace);
        assert_trace_preconditions(&trace, scenario.name());

        let baseline = run_autoscaled("target-tracking", &scenario, &trace, &suite, 1, 1);
        let (report, actions, _ticks) = &baseline;
        let joins =
            actions.iter().filter(|a| a.change == MembershipChange::Join).count();
        let fails =
            actions.iter().filter(|a| a.change == MembershipChange::Fail).count();
        assert!(joins >= 1, "{}: an SLO-violating window forces a join", scenario.name());
        assert!(fails >= 1, "{}: an idle attainment-1.0 window forces a fail", scenario.name());
        assert_actions_priced(actions, report, scenario.name());

        for (threads, window) in [(2usize, 16usize), (8, 64)] {
            let other =
                run_autoscaled("target-tracking", &scenario, &trace, &suite, threads, window);
            assert_eq!(
                other, baseline,
                "{}: threads {threads} window {window} must be bit-identical",
                scenario.name()
            );
        }
    }
}

#[test]
fn scripted_mass_interruption_events_are_priced_like_policy_actions() {
    let suite = small_suite();
    let scenario = Scenario::mass_interruption();
    let mut trace = base_trace([0.2, 0.6, 0.2]);
    scenario.shape_arrivals(&mut trace); // identity for this scenario
    let span = trace.last().unwrap().arrival_s;

    // A static policy keeps the scripted events the only membership
    // changes, so both reclaimed nodes must surface as priced failures.
    let mut config = cluster_config(2, 16, &scenario);
    config.events.extend(scenario.membership_events(START_ALIVE, span));
    let mut run = AutoscaleRun::new(Box::new(StaticPolicy), autoscale_cfg());
    let mut svc = ClusterService::new(config);
    let report = svc.replay_autoscaled(&trace, &suite, &NoOracle, &mut run);

    assert!(run.actions.is_empty());
    let scripted_at = span / 3.0;
    let scripted: Vec<usize> = report
        .rebalances
        .iter()
        .filter(|rb| rb.kind == RebalanceKind::NodeFailure && rb.at_s == scripted_at)
        .map(|rb| rb.node)
        .collect();
    assert_eq!(
        scripted,
        vec![2, 3],
        "the interruption reclaims the two highest-indexed alive nodes, priced"
    );
    assert_eq!(report.epoch, 2, "each applied failure bumps the membership epoch");
}

#[test]
fn straggler_multipliers_reach_the_replay() {
    // The straggler scenario's multiplier vector must actually change the
    // replay (node 0 serves 4x slower), and the steady scenario's empty
    // vector must not.
    let suite = small_suite();
    let trace = base_trace([0.2, 0.6, 0.2]);

    let mut steady_svc = ClusterService::new(cluster_config(2, 16, &Scenario::steady()));
    let steady = steady_svc.replay(&trace, &suite, &NoOracle);
    let mut empty_mult = cluster_config(2, 16, &Scenario::steady());
    assert!(empty_mult.node_service_multipliers.is_empty());
    empty_mult.node_service_multipliers = vec![1.0; SLOTS];
    let mut unit_svc = ClusterService::new(empty_mult);
    let unit = unit_svc.replay(&trace, &suite, &NoOracle);
    assert_eq!(unit, steady, "all-1.0 multipliers are the identity");

    // The straggler scenario's vector slows exactly node 0.
    let straggler_cfg = cluster_config(2, 16, &Scenario::straggler());
    assert_eq!(straggler_cfg.node_service_multipliers, vec![4.0, 1.0, 1.0, 1.0, 1.0, 1.0]);

    // Whether node 0 owns traffic under this seed is a routing accident, so
    // the plumb-through proof slows *every* node: any replay runs cold
    // flights somewhere, and a fleet-wide 4x multiplier must change the
    // latency surface.
    let mut slow_cfg = cluster_config(2, 16, &Scenario::steady());
    slow_cfg.node_service_multipliers = vec![4.0; SLOTS];
    let mut slow_svc = ClusterService::new(slow_cfg);
    let slow = slow_svc.replay(&trace, &suite, &NoOracle);
    assert!(steady.overall.flights_run > 0, "cold flights exist to be slowed");
    assert_ne!(slow, steady, "a fleet-wide 4x multiplier must change the report");
}
