//! Integration: the three layers compose. Executes every AOT artifact on the
//! PJRT CPU client against its pure-jnp reference, then runs the CudaForge
//! workflow on the artifact-bound anchor tasks with the real oracle.
//!
//! Requires `make artifacts` (skips, loudly, if artifacts are absent) and a
//! build with `--features pjrt` (compiles to an empty test crate otherwise).

#![cfg(feature = "pjrt")]

#![allow(clippy::disallowed_methods)]

use std::path::PathBuf;

use cudaforge::gpu::RTX6000_ADA;
use cudaforge::runtime::oracle::{RealOracle, VerificationMatrix};
use cudaforge::runtime::Engine;
use cudaforge::tasks;
use cudaforge::workflow::{run_task, Strategy, WorkflowConfig};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine() -> Option<Engine> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing; run `make artifacts`");
        return None;
    }
    Some(Engine::new(artifacts_dir()).expect("engine"))
}

#[test]
fn every_artifact_verdict_matches_its_label() {
    let Some(mut engine) = engine() else { return };
    let matrix = VerificationMatrix::build(&mut engine, 42).expect("verification");
    assert!(matrix.verdicts.len() >= 25, "{} verdicts", matrix.verdicts.len());
    for (name, v) in &matrix.verdicts {
        if name.contains("bug_") {
            assert!(
                !v.passes,
                "intentionally-buggy artifact {name} unexpectedly matches its \
                 reference (max|diff|={:.3e})",
                v.max_abs_diff
            );
        } else {
            assert!(
                v.passes,
                "correct artifact {name} fails tolerance (max|diff|={:.3e})",
                v.max_abs_diff
            );
        }
    }
    assert!(matrix.is_consistent());
}

#[test]
fn verification_is_stable_across_input_seeds() {
    let Some(mut engine) = engine() else { return };
    for seed in [1u64, 99, 12345] {
        let m = VerificationMatrix::build(&mut engine, seed).expect("verification");
        assert!(m.is_consistent(), "seed {seed} produced inconsistent verdicts");
    }
}

#[test]
fn workflow_on_anchor_tasks_uses_real_numerics() {
    let Some(mut engine) = engine() else { return };
    let matrix = VerificationMatrix::build(&mut engine, 7).expect("verification");
    let oracle = RealOracle::new(matrix);
    let mut bound_checked = 0;
    for id in ["L1-95", "L1-12", "L1-24", "L2-51", "L3-5", "L1-40", "L1-47"] {
        let task = tasks::by_id(id).expect(id);
        assert!(task.binding.is_some(), "{id} should be artifact-bound");
        let wf = WorkflowConfig::cudaforge(&RTX6000_ADA, 2024);
        let r = run_task(&wf, &task, &oracle);
        assert_eq!(r.rounds.len(), 10);
        assert!(r.oracle_checks > 0, "{id}: oracle never consulted");
        bound_checked += 1;
        // On anchors CudaForge should essentially always end up correct: the
        // correction loop sees real mismatches and fixes them.
        assert!(r.correct, "{id} never produced a correct kernel");
    }
    assert_eq!(bound_checked, 7);
}

#[test]
fn oracle_and_model_agree_on_clean_and_buggy_configs() {
    // The modelled check and the artifact-backed check must tell the same
    // story: clean configs pass, runtime-buggy configs mismatch.
    let Some(mut engine) = engine() else { return };
    let matrix = VerificationMatrix::build(&mut engine, 3).expect("verification");
    let oracle = RealOracle::new(matrix);
    let task = tasks::by_id("L1-95").unwrap();
    let mut cfg = cudaforge::kernel::KernelConfig::naive();
    cfg.legalize(&RTX6000_ADA);
    use cudaforge::workflow::{modelled_check, CheckOutcome, CorrectnessOracle};
    assert_eq!(oracle.check(&task, &cfg), Some(CheckOutcome::Pass));
    assert_eq!(modelled_check(&cfg), CheckOutcome::Pass);
    cfg.bugs.push(cudaforge::kernel::Bug::UninitValue);
    assert!(matches!(oracle.check(&task, &cfg), Some(CheckOutcome::Mismatch(_))));
    assert!(matches!(modelled_check(&cfg), CheckOutcome::Mismatch(_)));
}

#[test]
fn kevin_and_agentic_run_with_oracle() {
    let Some(mut engine) = engine() else { return };
    let matrix = VerificationMatrix::build(&mut engine, 5).expect("verification");
    let oracle = RealOracle::new(matrix);
    let task = tasks::by_id("L1-95").unwrap();
    for strategy in [Strategy::Kevin, Strategy::AgenticBaseline] {
        let wf = WorkflowConfig::cudaforge(&RTX6000_ADA, 17).with_strategy(strategy);
        let r = run_task(&wf, &task, &oracle);
        assert!(r.oracle_checks > 0, "{strategy:?} skipped the oracle");
    }
}
