//! Integration: tenant isolation under the deficit-weighted-fair
//! dispatcher and the front-door rate limiter — the invariant the
//! scheduler exists for (a 10× hog burst must not blow up a well-behaved
//! tenant's p99 when fair dispatch is on, and provably does when it is
//! off), the reconciliation identities between the per-tenant report
//! sections and the cluster totals (requests, sheds, throttles, and peak
//! backlog all partition), and the per-tenant columns of `metrics.csv`
//! summing back to the aggregate columns.

#![allow(clippy::disallowed_methods)]

use std::path::Path;

use cudaforge::cluster::{ClusterConfig, ClusterReport, ClusterService, TenantSpec};
use cudaforge::gpu;
use cudaforge::report::cluster_table;
use cudaforge::service::queue::Priority;
use cudaforge::service::traffic::TrafficRequest;
use cudaforge::service::ServiceConfig;
use cudaforge::tasks;
use cudaforge::tasks::TaskSpec;
use cudaforge::trace::{metrics, Observer, Recorder, TraceMeta};
use cudaforge::workflow::NoOracle;

/// A hand-built request at an explicit simulated instant.
fn req_at(
    task_index: usize,
    gpu_key: &str,
    priority: Priority,
    tenant: usize,
    arrival_s: f64,
) -> TrafficRequest {
    TrafficRequest {
        task_index,
        gpu: gpu::by_key(gpu_key).unwrap(),
        priority,
        tenant,
        arrival_s,
    }
}

/// The isolation scenario's deployment: one node, one simulated worker
/// (so dispatch order is the whole story), an unbounded queue and no
/// quotas (so *only* the scheduler can protect a tenant), and two
/// equal-weight tenants — `well` (index 0) is the bystander, `hog`
/// (index 1) the burster.
fn isolation_config(fair: bool) -> ClusterConfig {
    ClusterConfig {
        service: ServiceConfig {
            threads: 2,
            window: 16,
            sim_workers: 1,
            queue_depth: usize::MAX,
            seed: 7,
            fair_dispatch: fair,
            ..ServiceConfig::default()
        },
        nodes: 1,
        tenants: vec![TenantSpec::new("well", 1.0), TenantSpec::new("hog", 1.0)],
        tenant_quotas: false,
        ..ClusterConfig::default()
    }
}

fn isolation_replay(trace: &[TrafficRequest], fair: bool, suite: &[TaskSpec]) -> ClusterReport {
    let mut svc = ClusterService::new(isolation_config(fair));
    svc.replay(trace, suite, &NoOracle)
}

/// Zero-contention latency of one task: replay it alone and read the
/// lone request's latency back out of the tenant section. Deterministic,
/// and bit-identical to what the same flight costs inside a bigger
/// replay (cold run, same gpu, no warm seeds — distinct tasks never
/// cross-seed).
fn solo_latency_s(task_index: usize, suite: &[TaskSpec]) -> f64 {
    let trace = [req_at(task_index, "rtx6000", Priority::Interactive, 0, 0.0)];
    isolation_replay(&trace, true, suite).per_tenant[0].p99_latency_s
}

/// Like the report goldens, but self-blessing: the expected rendering is
/// a function of the simulated workload (not a hand-written fixture), so
/// the first `cargo test` run writes the golden and later runs compare
/// against it. `UPDATE_GOLDEN=1` re-blesses after an intentional format
/// change.
fn assert_golden(name: &str, rendered: &str) {
    let path = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden"))
        .join(format!("{name}.txt"));
    let bless = std::env::var("UPDATE_GOLDEN")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    if bless || !path.exists() {
        std::fs::write(&path, rendered).expect("bless golden file");
        return;
    }
    let want = std::fs::read_to_string(&path).expect("read golden file");
    assert_eq!(
        rendered, want,
        "{name} drifted from tests/golden/{name}.txt; \
         run UPDATE_GOLDEN=1 cargo test to bless an intentional change"
    );
}

/// The isolation invariant, both directions in one test: under a 10×
/// same-priority hog burst, fair dispatch keeps the well-behaved
/// tenant's p99 under 2× its uncontended baseline, and the historical
/// strict arrival order provably breaches that bound on the *same*
/// traffic.
///
/// The scenario is engineered from measured service times so the margin
/// on both assertions is structural, not luck: the bystander's single
/// request lands midway through the hog's 4th flight, so under fair
/// dispatch it waits at most half of one short hog flight (the scheduler
/// picks it at the next completion — its clamped deficit is below the
/// hog's charged deficit), while under strict order it waits for the
/// hog's entire remaining backlog.
#[test]
fn hog_burst_leaves_the_well_behaved_tenants_p99_intact_only_under_fair_dispatch() {
    let suite = tasks::kernelbench();

    // Probe solo latencies for a pool of candidate tasks, then cast the
    // bystander as the *longest* task and the hog as the ten longest
    // tasks that still sit clearly below it — so half a hog flight is
    // well under one bystander flight (fair stays < 2×), while ~6.5
    // remaining hog flights are well over one (strict breaches).
    let probes: Vec<f64> = (0..30).map(|i| solo_latency_s(i, &suite)).collect();
    let well_task = (0..probes.len())
        .max_by(|&a, &b| probes[a].partial_cmp(&probes[b]).unwrap())
        .unwrap();
    let well_solo = probes[well_task];
    assert!(well_solo > 0.0, "the bystander's flight must take simulated time");
    let mut hog_tasks: Vec<usize> = (0..probes.len())
        .filter(|&i| i != well_task && probes[i] <= 0.95 * well_solo)
        .collect();
    hog_tasks
        .sort_by(|&a, &b| probes[b].partial_cmp(&probes[a]).unwrap().then(a.cmp(&b)));
    assert!(
        hog_tasks.len() >= 10,
        "need 10 probe tasks clearly shorter than the longest ({well_solo}s): {probes:?}"
    );
    hog_tasks.truncate(10);

    // With one worker and a single burst at t=0 the hog's flights run in
    // submission order, so their completion instants are the probe
    // prefix-sums; land the bystander midway through the 4th flight.
    let c3: f64 = hog_tasks[..3].iter().map(|&i| probes[i]).sum();
    let arrival = c3 + probes[hog_tasks[3]] / 2.0;
    let well_req = || req_at(well_task, "rtx6000", Priority::Interactive, 0, arrival);

    // Baseline: the bystander with the cluster to itself.
    let base = isolation_replay(&[well_req()], true, &suite);
    let p99_base = base.per_tenant[0].p99_latency_s;
    // Equal up to one rounding step of `(arrival + service) - arrival`.
    assert!(
        (p99_base - well_solo).abs() < 1e-6 * well_solo,
        "an uncontended request pays service time only: {p99_base}s vs probe {well_solo}s"
    );

    // The 10× burst: ten distinct hog flights at t=0, ahead of the
    // bystander in arrival order.
    let mut burst: Vec<TrafficRequest> = hog_tasks
        .iter()
        .map(|&i| req_at(i, "rtx6000", Priority::Interactive, 1, 0.0))
        .collect();
    burst.push(well_req());

    let fair = isolation_replay(&burst, true, &suite);
    assert_eq!(fair.per_tenant[1].requests, 10 * fair.per_tenant[0].requests);
    assert_eq!(fair.overall.rejected, 0, "nothing sheds: isolation is dispatch-only here");
    let p99_fair = fair.per_tenant[0].p99_latency_s;
    assert!(
        p99_fair < 2.0 * p99_base,
        "fair dispatch must keep the bystander's p99 under 2x its baseline: \
         {p99_fair}s vs baseline {p99_base}s"
    );

    let strict = isolation_replay(&burst, false, &suite);
    let p99_strict = strict.per_tenant[0].p99_latency_s;
    assert!(
        p99_strict >= 2.0 * p99_base,
        "strict arrival order must make the bystander wait out the hog's backlog: \
         {p99_strict}s vs baseline {p99_base}s"
    );
    assert!(
        p99_strict > p99_fair,
        "the breach must come from dispatch order, not noise: \
         strict {p99_strict}s vs fair {p99_fair}s"
    );

    // The fair run's report is the isolation story a reader sees; pin its
    // rendering (per-tenant p50/p95/p99, shed split, peak depth rows).
    assert_golden("isolation_hog_burst", &cluster_table(&fair).render());
}

/// Per-tenant accounting must partition the cluster totals exactly:
/// requests, sheds (with the quota/rate split), and served counts sum
/// over tenants to the aggregate figures, and the per-tenant peak
/// backlogs bracket the cluster peak. Driven by a deterministic overload
/// with *both* shed paths live — a front-door token bucket throttling the
/// hog's tail and fair-share quotas shedding inside admission.
#[test]
fn per_tenant_sections_reconcile_with_cluster_totals() {
    let suite = tasks::kernelbench();
    // Hog (tenant 0) bursts 10 distinct standard requests at t=0 with a
    // burst-6 bucket: exactly 4 throttle at the door. The 6 that get in
    // replay the fair-share scenario (queue_depth 4, equal weights) that
    // sheds 2 on quota. The light tenant's 3 requests all pass its own
    // bucket.
    let mut trace: Vec<TrafficRequest> = (0..10)
        .map(|i| req_at(i, "rtx6000", Priority::Standard, 0, 0.0))
        .collect();
    trace.push(req_at(10, "rtx6000", Priority::Standard, 1, 0.0));
    trace.push(req_at(11, "rtx6000", Priority::Standard, 1, 0.0));
    trace.push(req_at(12, "rtx6000", Priority::Standard, 1, 0.0));

    let mut svc = ClusterService::new(ClusterConfig {
        nodes: 1,
        tenants: vec![TenantSpec::new("hog", 1.0), TenantSpec::new("light", 1.0)],
        tenant_quotas: true,
        service: ServiceConfig {
            threads: 2,
            window: 16,
            sim_workers: 1,
            queue_depth: 4,
            seed: 7,
            tenant_rate: Some(0.001),
            tenant_burst: Some(6.0),
            ..ServiceConfig::default()
        },
        ..ClusterConfig::default()
    });
    let r = svc.replay(&trace, &suite, &NoOracle);
    let o = &r.overall;

    // Both shed paths actually fired, on the tenant that earned them.
    assert_eq!(r.per_tenant[0].throttled, 4, "10 arrivals through a burst-6 bucket");
    assert_eq!(r.per_tenant[1].throttled, 0);
    assert_eq!(o.rate_limited, 4);
    assert!(r.quota_shed > 0, "the admitted hog backlog must overflow its fair share");
    assert!(
        r.per_tenant[0].quota_shed > r.per_tenant[1].quota_shed,
        "quota pressure lands on the hog"
    );

    // The partition identities the report sections promise.
    let sum_requests: usize = r.per_tenant.iter().map(|t| t.requests).sum();
    let sum_served: usize = r.per_tenant.iter().map(|t| t.served).sum();
    let sum_rejected: u64 = r.per_tenant.iter().map(|t| t.rejected).sum();
    let sum_quota: u64 = r.per_tenant.iter().map(|t| t.quota_shed).sum();
    let sum_throttled: u64 = r.per_tenant.iter().map(|t| t.throttled).sum();
    assert_eq!(sum_requests, o.requests);
    assert_eq!(sum_rejected, o.rejected);
    assert_eq!(sum_quota, r.quota_shed);
    assert_eq!(sum_throttled, o.rate_limited);
    assert_eq!(sum_served, o.requests - o.rejected as usize);
    for t in &r.per_tenant {
        assert_eq!(t.served, t.requests - t.rejected as usize, "tenant {}", t.tenant);
        assert!(t.throttled + t.quota_shed <= t.rejected, "tenant {}", t.tenant);
    }

    // Per-tenant peaks bracket the cluster peak: no single tenant's
    // backlog exceeds it, and together the tenants account for it.
    let max_peak = r.per_tenant.iter().map(|t| t.peak_queue_depth).max().unwrap();
    let sum_peak: usize = r.per_tenant.iter().map(|t| t.peak_queue_depth).sum();
    assert!(max_peak > 0, "the burst must queue");
    assert!(max_peak <= o.peak_queue_depth);
    assert!(o.peak_queue_depth <= sum_peak);
}

/// The per-tenant `metrics.csv` columns must reconcile with the
/// aggregate columns of the same CSV: over the whole series,
/// `sheds == shed_<a> + shed_<b>` (and the reason columns partition the
/// sheds), and every admitted request is eventually served to exactly
/// one tenant column.
#[test]
fn metrics_csv_tenant_columns_sum_to_the_aggregates() {
    let suite = tasks::kernelbench();
    let mut trace: Vec<TrafficRequest> = (0..10)
        .map(|i| req_at(i, "rtx6000", Priority::Standard, 0, 0.0))
        .collect();
    trace.push(req_at(10, "rtx6000", Priority::Standard, 1, 0.0));
    trace.push(req_at(11, "rtx6000", Priority::Standard, 1, 0.0));

    let mut svc = ClusterService::new(ClusterConfig {
        nodes: 1,
        tenants: vec![TenantSpec::new("hog", 1.0), TenantSpec::new("light", 1.0)],
        tenant_quotas: true,
        service: ServiceConfig {
            threads: 2,
            window: 16,
            sim_workers: 1,
            queue_depth: 4,
            seed: 7,
            tenant_rate: Some(0.001),
            tenant_burst: Some(6.0),
            ..ServiceConfig::default()
        },
        ..ClusterConfig::default()
    });
    let mut recorder = Recorder::default();
    let mut obs = Observer::new(&mut recorder);
    let r = svc.replay_observed(&trace, &suite, &NoOracle, &mut obs);
    assert!(r.overall.rate_limited > 0 && r.quota_shed > 0);

    let mut meta = TraceMeta::new("cluster", 1, 1);
    meta.tenants = vec!["hog".to_string(), "light".to_string()];
    let csv = metrics::time_series(&meta, &recorder.events);
    let lines: Vec<&str> = csv.lines().collect();
    let header: Vec<&str> = lines[0].split(',').collect();
    let col = |name: &str| -> usize {
        header.iter().position(|h| *h == name).unwrap_or_else(|| panic!("no column {name}"))
    };
    let sum = |name: &str| -> u64 {
        let c = col(name);
        lines[1..].iter().map(|l| l.split(',').nth(c).unwrap().parse::<u64>().unwrap()).sum()
    };

    // The per-tenant shed columns partition the aggregate shed column,
    // and the reason columns partition it too.
    assert_eq!(sum("sheds"), sum("shed_hog") + sum("shed_light"));
    assert_eq!(
        sum("sheds"),
        sum("shed_depth") + sum("shed_quota") + sum("shed_routing") + sum("shed_rate")
    );
    assert_eq!(sum("shed_rate"), r.overall.rate_limited);
    assert_eq!(sum("shed_quota"), r.quota_shed);
    assert_eq!(sum("sheds"), r.overall.rejected);
    // Every non-shed request lands in exactly one tenant's served column.
    assert_eq!(
        sum("served_hog") + sum("served_light"),
        (r.overall.requests - r.overall.rejected as usize) as u64
    );
}
