//! Integration: the static-analysis gate (DESIGN: analysis layer).
//!
//! Two contracts matter. (1) Lint-off is the exact pre-analyzer behaviour:
//! the gate draws no rng and charges nothing when `WorkflowConfig.lint` is
//! `None`, so replays stay bit-identical and cache fingerprints unchanged.
//! (2) Lint-on pays for itself on bug-injected seeds: a high-confidence
//! pre-compile diagnostic buys a Coder repair instead of spending the
//! compile+test stage on a candidate the analyzer already condemned.

#![allow(clippy::disallowed_methods)]

use cudaforge::analysis;
use cudaforge::gpu::RTX6000_ADA;
use cudaforge::service::ServiceConfig;
use cudaforge::tasks::by_id;
use cudaforge::workflow::{run_task, LintGate, LintStats, NoOracle, WorkflowConfig};

fn wf_off(seed: u64) -> WorkflowConfig {
    WorkflowConfig::cudaforge(&RTX6000_ADA, seed)
}

fn wf_on(seed: u64) -> WorkflowConfig {
    WorkflowConfig::cudaforge(&RTX6000_ADA, seed).with_lint(LintGate::default())
}

/// Lint-off runs are bit-identical replays of the pre-analyzer engine: the
/// whole `TaskResult` (every round, ledger cent, config field) reproduces,
/// and the lint accounting stays all-zero.
#[test]
fn lint_off_replays_bit_identical_with_zero_accounting() {
    let task = by_id("L1-95").unwrap();
    let a = run_task(&wf_off(2024), &task, &NoOracle);
    let b = run_task(&wf_off(2024), &task, &NoOracle);
    assert_eq!(a, b, "lint-off replay diverged");
    assert_eq!(a.lint, LintStats::default(), "lint-off must charge nothing");
    assert!(a.correct, "seed 2024 baseline run should still converge");
}

/// The service fingerprint only folds the gate in when it is set: `None`
/// keeps every pre-analyzer cache snapshot addressable, while gate parameter
/// changes address different cache entries.
#[test]
fn fingerprint_unchanged_when_lint_off_distinct_when_on() {
    let task = by_id("L1-95").unwrap();
    let off = ServiceConfig::default();
    assert!(off.lint.is_none(), "lint must default to off");

    let on = ServiceConfig { lint: Some(LintGate::default()), ..ServiceConfig::default() };
    let on_lax = ServiceConfig {
        lint: Some(LintGate { repair_confidence: 0.8, ..LintGate::default() }),
        ..ServiceConfig::default()
    };

    let fp_off = off.fingerprint_of(&task, &RTX6000_ADA);
    assert_eq!(fp_off, off.fingerprint_of(&task, &RTX6000_ADA), "fingerprint must be stable");
    let fp_on = on.fingerprint_of(&task, &RTX6000_ADA);
    assert_ne!(fp_off, fp_on, "enabling the gate must address a different cache entry");
    assert_ne!(fp_on, on_lax.fingerprint_of(&task, &RTX6000_ADA), "gate params are part of the address");
}

/// On a seed whose round-1 candidate carries a compile-class defect, the
/// lint-off run burns round 1 on a doomed compile while the lint-on run
/// repairs pre-compile and books the avoided check + Judge spend. The seed
/// scan is deterministic: `analysis::round_one_candidate` reproduces exactly
/// the candidate `run_task` generates for that seed.
#[test]
fn lint_on_saves_a_correctness_round_on_a_bug_injected_seed() {
    let task = by_id("L1-95").unwrap();
    let coder = wf_off(0).coder;
    let first_correct =
        |r: &cudaforge::workflow::TaskResult| r.rounds.iter().find(|x| x.correct).map(|x| x.round);

    let mut bug_seeds = 0u32;
    for seed in 1..=64u64 {
        let candidate = analysis::round_one_candidate(coder, &task, &RTX6000_ADA, seed);
        if !candidate.has_compile_error() {
            continue;
        }
        bug_seeds += 1;

        let off = run_task(&wf_off(seed), &task, &NoOracle);
        assert!(
            !off.rounds[0].compiled,
            "seed {seed}: lint-off must spend round 1 on the doomed compile"
        );

        let on = run_task(&wf_on(seed), &task, &NoOracle);
        assert!(on.lint.diagnostics >= 1, "seed {seed}: injected compile bug must be flagged");

        if on.lint.checks_saved >= 1 {
            assert!(on.lint.repairs >= 1 && on.lint.bugs_repaired >= 1);
            assert!(on.lint.api_usd_saved > 0.0, "saved Judge correction must be priced");
            assert!(on.lint.wall_s_saved > 0.0, "skipped compile must be priced");
            // The repair may not shorten this particular trajectory (the
            // rewrite can introduce a fresh runtime defect); demand a seed
            // where it demonstrably does not lengthen it.
            match (first_correct(&on), first_correct(&off)) {
                (Some(n), Some(f)) if n <= f => return,
                (Some(_), None) => return,
                _ => {}
            }
        }
    }
    assert!(bug_seeds > 0, "no compile-bug seed in 1..=64 — coder model drifted?");
    panic!("no seed in 1..=64 demonstrated a saved correctness round with lint on");
}
