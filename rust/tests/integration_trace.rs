//! Integration: the deterministic flight recorder — recording never
//! perturbs a replay (trace-on reports equal trace-off reports, bit for
//! bit), the recorded stream and every artifact derived from it
//! (`events.jsonl`, `metrics.csv`, the Chrome trace) are bit-identical
//! across host thread counts and `window` batch sizes, `trace --explain`
//! reconstructs each decision path (cache hit, cold miss, cross-GPU warm
//! start, quota shed, lint short-circuit) from the event log, and the
//! `--profile` stage timers attribute (nearly) all replay wall time.

#![allow(clippy::disallowed_methods)]

use cudaforge::analysis;
use cudaforge::cluster::{ClusterConfig, ClusterReport, ClusterService, MembershipEvent, TenantSpec};
use cudaforge::gpu;
use cudaforge::report::{cluster_table, service_table};
use cudaforge::service::queue::Priority;
use cudaforge::service::traffic::{generate, TrafficConfig, TrafficRequest};
use cudaforge::service::{KernelService, ServiceConfig};
use cudaforge::tasks;
use cudaforge::trace::profile::{Profiler, Stage};
use cudaforge::trace::{
    chrome, events_jsonl, explain, metrics, NullSink, Observer, Recorder, TraceMeta, SCHEMA,
};
use cudaforge::util::json::Json;
use cudaforge::workflow::{run_task, LintGate, NoOracle};

/// A hand-built request at an explicit simulated instant.
fn req_at(
    task_index: usize,
    gpu_key: &str,
    priority: Priority,
    tenant: usize,
    arrival_s: f64,
) -> TrafficRequest {
    TrafficRequest {
        task_index,
        gpu: gpu::by_key(gpu_key).unwrap(),
        priority,
        tenant,
        arrival_s,
    }
}

/// Deterministically pick a task whose cold rtx6000 run caches a usable
/// kernel (the anchor-probe idiom shared with the cluster tests).
fn anchor_task(cfg: &ServiceConfig) -> usize {
    let suite = tasks::kernelbench();
    (0..suite.len())
        .find(|i| {
            let wf = cfg.base_workflow(gpu::by_key("rtx6000").unwrap());
            let r = run_task(&wf, &suite[*i], &NoOracle);
            r.correct && r.best_speedup > 0.0 && r.best_config.is_some()
        })
        .expect("some task solves cold on rtx6000")
}

#[test]
fn recording_never_changes_the_service_report() {
    let suite = tasks::kernelbench();
    let trace = generate(
        suite.len(),
        &TrafficConfig { requests: 200, seed: 7, ..TrafficConfig::default() },
    );
    let cfg = ServiceConfig { threads: 2, window: 16, seed: 7, ..ServiceConfig::default() };

    let mut plain = KernelService::new(cfg.clone());
    let expected = plain.replay(&trace, &suite, &NoOracle);

    // A recording observer: same report, plus the event stream.
    let mut recorder = Recorder::default();
    let mut obs = Observer::new(&mut recorder);
    let mut svc = KernelService::new(cfg.clone());
    let got = svc.replay_observed(&trace, &suite, &NoOracle, &mut obs);
    assert_eq!(got, expected, "recording must never perturb the replay");
    assert!(!recorder.events.is_empty());
    let admits = recorder.events.iter().filter(|e| e.kind == "request.admit").count();
    assert_eq!(admits, trace.len(), "exactly one admission decision per arrival");
    let completes = recorder.events.iter().filter(|e| e.kind == "flight.complete").count();
    assert_eq!(completes, expected.flights_run, "one completion span per executed flight");

    // An explicit NullSink observer: also identical (the no-op path).
    let mut null = NullSink;
    let mut obs = Observer::new(&mut null);
    let mut svc = KernelService::new(cfg);
    assert_eq!(svc.replay_observed(&trace, &suite, &NoOracle, &mut obs), expected);
}

/// The untraced entry points (`replay`, cluster `replay`) are thin NullSink
/// wrappers over the observed implementations — so traced-off output must
/// stay *byte*-identical, not merely `PartialEq`-equal: the rendered report
/// tables and their CSV forms are compared as strings. This pins the
/// wrapper contract through the hot-path storage rewrites (interned
/// fingerprints, the SoA flight arena, the global event heap).
#[test]
fn untraced_wrappers_render_byte_identical_reports() {
    let suite = tasks::kernelbench();
    let trace = generate(
        suite.len(),
        &TrafficConfig { requests: 200, seed: 7, ..TrafficConfig::default() },
    );
    let cfg = ServiceConfig { threads: 1, window: 16, seed: 7, ..ServiceConfig::default() };

    let mut plain = KernelService::new(cfg.clone());
    let a = plain.replay(&trace, &suite, &NoOracle);
    let mut null = NullSink;
    let mut obs = Observer::new(&mut null);
    let mut svc = KernelService::new(cfg);
    let b = svc.replay_observed(&trace, &suite, &NoOracle, &mut obs);
    assert_eq!(a, b);
    assert_eq!(
        service_table(&a).render(),
        service_table(&b).render(),
        "service table must render byte-identically traced-off vs untraced"
    );
    assert_eq!(service_table(&a).to_csv(), service_table(&b).to_csv());

    let ctrace = generate(
        suite.len(),
        &TrafficConfig {
            requests: 200,
            seed: 7,
            tenant_mix: vec![("alpha".to_string(), 3.0), ("beta".to_string(), 1.0)],
            ..TrafficConfig::default()
        },
    );
    let ccfg = ClusterConfig {
        nodes: 3,
        tenants: vec![TenantSpec::new("alpha", 3.0), TenantSpec::new("beta", 1.0)],
        tenant_quotas: true,
        service: ServiceConfig { threads: 1, window: 16, seed: 7, ..ServiceConfig::default() },
        ..ClusterConfig::default()
    };
    let mut cplain = ClusterService::new(ccfg.clone());
    let ca = cplain.replay(&ctrace, &suite, &NoOracle);
    let mut cnull = NullSink;
    let mut cobs = Observer::new(&mut cnull);
    let mut csvc = ClusterService::new(ccfg);
    let cb = csvc.replay_observed(&ctrace, &suite, &NoOracle, &mut cobs);
    assert_eq!(ca, cb);
    assert_eq!(
        cluster_table(&ca).render(),
        cluster_table(&cb).render(),
        "cluster table must render byte-identically traced-off vs untraced"
    );
    assert_eq!(cluster_table(&ca).to_csv(), cluster_table(&cb).to_csv());
}

/// The full cluster feature mix (sharding, tenants + quotas, a fail +
/// rejoin cycle, cross-node warm margins) replayed under a recorder.
fn recorded_cluster(threads: usize, window: usize) -> (ClusterReport, Recorder) {
    let suite = tasks::kernelbench();
    let trace = generate(
        suite.len(),
        &TrafficConfig {
            requests: 300,
            seed: 7,
            tenant_mix: vec![("alpha".to_string(), 3.0), ("beta".to_string(), 1.0)],
            ..TrafficConfig::default()
        },
    );
    let fail_at = trace[trace.len() / 2].arrival_s;
    let rejoin_at = trace[3 * trace.len() / 4].arrival_s;
    let mut svc = ClusterService::new(ClusterConfig {
        nodes: 3,
        tenants: vec![TenantSpec::new("alpha", 3.0), TenantSpec::new("beta", 1.0)],
        tenant_quotas: true,
        transfer_latency_s: 30.0,
        warm_locality_margin: 0.25,
        events: vec![
            MembershipEvent::fail(1, fail_at),
            MembershipEvent::join(1, rejoin_at),
        ],
        service: ServiceConfig {
            threads,
            window,
            sim_workers: 2,
            queue_depth: 8,
            seed: 7,
            ..ServiceConfig::default()
        },
        ..ClusterConfig::default()
    });
    let mut recorder = Recorder::default();
    let mut obs = Observer::new(&mut recorder);
    let report = svc.replay_observed(&trace, &suite, &NoOracle, &mut obs);
    (report, recorder)
}

fn cluster_meta() -> TraceMeta {
    let mut meta = TraceMeta::new("cluster", 3, 2);
    meta.tenants = vec!["alpha".to_string(), "beta".to_string()];
    meta
}

#[test]
fn recorded_artifacts_are_bit_identical_across_threads_and_window() {
    let meta = cluster_meta();
    let (base_report, base_rec) = recorded_cluster(1, 16);
    let base_jsonl = events_jsonl(&meta, &base_rec.events);
    let base_csv = metrics::time_series(&meta, &base_rec.events);
    assert!(base_rec.events.iter().any(|e| e.kind == "membership.fail"));
    assert!(base_rec.events.iter().any(|e| e.kind == "membership.join"));

    for (threads, window) in [(2usize, 16usize), (8, 16), (2, 1), (2, 64)] {
        let (report, rec) = recorded_cluster(threads, window);
        assert_eq!(report, base_report, "threads {threads} window {window}");
        assert_eq!(
            events_jsonl(&meta, &rec.events),
            base_jsonl,
            "events.jsonl must be bit-identical at threads {threads} window {window}"
        );
        assert_eq!(
            metrics::time_series(&meta, &rec.events),
            base_csv,
            "metrics.csv must be bit-identical at threads {threads} window {window}"
        );
    }

    // The JSONL leads with the schema-stamped header, then parseable
    // event lines in simulated-time order.
    let mut lines = base_jsonl.lines();
    let header = Json::parse(lines.next().unwrap()).unwrap();
    assert_eq!(header.get("schema").and_then(Json::as_str), Some(SCHEMA));
    assert_eq!(header.get("layer").and_then(Json::as_str), Some("cluster"));
    assert_eq!(header.get("version").and_then(Json::as_str), Some(cudaforge::version()));
    let mut prev = f64::NEG_INFINITY;
    for line in lines {
        let ev = Json::parse(line).unwrap();
        let at = ev.get("at_s").and_then(|v| v.as_f64()).unwrap();
        assert!(at >= prev, "events must be emitted in simulated-time order");
        prev = at;
    }
}

#[test]
fn chrome_export_of_a_recorded_replay_is_well_formed() {
    let meta = cluster_meta();
    let (report, rec) = recorded_cluster(2, 16);
    let j = chrome::chrome_trace(&meta, &rec.events);
    let evs = j.get("traceEvents").and_then(Json::as_arr).unwrap();
    assert!(!evs.is_empty());
    let mut prev = f64::NEG_INFINITY;
    let mut spans = 0usize;
    for ev in evs {
        for key in ["name", "ph", "ts", "pid", "tid"] {
            assert!(ev.get(key).is_some(), "chrome event missing {key}");
        }
        let ts = ev.get("ts").and_then(|v| v.as_f64()).unwrap();
        assert!(ts >= prev, "ts must be monotonic");
        prev = ts;
        if ev.get("ph").and_then(|v| v.as_str()) == Some("X") {
            spans += 1;
            assert!(ev.get("dur").and_then(|v| v.as_f64()).unwrap() >= 0.0);
        }
    }
    assert_eq!(spans, report.overall.flights_run, "one span per executed flight");
    assert_eq!(
        j.get("otherData").and_then(|o| o.get("build")).and_then(Json::as_str),
        Some(cudaforge::trace::build_stamp().as_str())
    );
}

#[test]
fn explain_covers_hit_miss_and_cross_gpu_warm_start() {
    let suite = tasks::kernelbench();
    let cfg = ServiceConfig { threads: 1, window: 1, seed: 7, ..ServiceConfig::default() };
    let anchor = anchor_task(&cfg);
    // Arrivals spaced far beyond any service time: t=0 runs cold and
    // caches, t=100k is a true cache hit, t=200k on a second GPU misses
    // its own fingerprint but warm-starts from the cached rtx6000 kernel.
    let trace = vec![
        req_at(anchor, "rtx6000", Priority::Standard, 0, 0.0),
        req_at(anchor, "rtx6000", Priority::Standard, 0, 100_000.0),
        req_at(anchor, "a100", Priority::Standard, 0, 200_000.0),
    ];
    let mut svc = KernelService::new(cfg.clone());
    let mut recorder = Recorder::default();
    let mut obs = Observer::new(&mut recorder);
    let r = svc.replay_observed(&trace, &suite, &NoOracle, &mut obs);
    assert_eq!(r.cache_hits, 1);
    assert_eq!(r.flights_run, 2);
    assert_eq!(r.warm_started, 1, "the a100 run seeds from the rtx6000 entry");

    let rtx = gpu::by_key("rtx6000").unwrap();
    let a100 = gpu::by_key("a100").unwrap();
    let fp_rtx = cfg.fingerprint_of(&suite[anchor], rtx).to_string();
    let fp_a100 = cfg.fingerprint_of(&suite[anchor], a100).to_string();
    let lines: Vec<Json> = recorder.events.iter().map(|e| e.to_json()).collect();

    // The cold fingerprint's story: miss → cold flight → cached → hit.
    let story = explain::explain_events(&lines, &fp_rtx);
    assert!(story.contains("new flight enqueued"), "{story}");
    assert!(story.contains("cold"), "{story}");
    assert!(story.contains("result cached"), "{story}");
    assert!(story.contains("cache HIT"), "{story}");

    // The second GPU's story: miss → warm lookup picks the local
    // cross-GPU seed (naming its source) → warm-seeded flight.
    let story = explain::explain_events(&lines, &fp_a100);
    assert!(story.contains("new flight enqueued"), "{story}");
    assert!(story.contains("warm lookup: local seed"), "{story}");
    assert!(story.contains(&fp_rtx), "the seed's source fingerprint is named: {story}");
    assert!(story.contains("warm-seeded"), "{story}");

    // The same story survives the write_dir → explain_dir round trip.
    let dir = std::env::temp_dir().join("cudaforge_trace_explain_roundtrip");
    let _ = std::fs::remove_dir_all(&dir);
    let meta = TraceMeta::new("service", 1, cfg.sim_workers);
    cudaforge::trace::write_dir(&dir, &meta, &recorder.events).unwrap();
    for artifact in ["events.jsonl", "chrome_trace.json", "metrics.csv"] {
        assert!(dir.join(artifact).exists(), "{artifact} must be written");
    }
    assert_eq!(explain::explain_dir(&dir, &fp_a100).unwrap(), story);
    assert!(explain::explain_dir(&dir, "ffffffffffffffff")
        .unwrap()
        .contains("no recorded events"));
}

#[test]
fn explain_covers_the_quota_shed_path() {
    let suite = tasks::kernelbench();
    // One node, queue_depth 4, equal weights => 2 backlog slots per
    // tenant; the hog's 5th and 6th distinct opens exceed its share
    // (the fair-share scenario from the cluster tests, recorded).
    let mut trace: Vec<TrafficRequest> = (0..6)
        .map(|i| req_at(i, "rtx6000", Priority::Standard, 0, 0.0))
        .collect();
    trace.push(req_at(6, "rtx6000", Priority::Standard, 1, 0.0));
    trace.push(req_at(7, "rtx6000", Priority::Standard, 1, 0.0));
    let mut svc = ClusterService::new(ClusterConfig {
        nodes: 1,
        tenants: vec![TenantSpec::new("hog", 1.0), TenantSpec::new("light", 1.0)],
        tenant_quotas: true,
        service: ServiceConfig {
            threads: 1,
            window: 32,
            sim_workers: 1,
            queue_depth: 4,
            seed: 7,
            ..ServiceConfig::default()
        },
        ..ClusterConfig::default()
    });
    let mut recorder = Recorder::default();
    let mut obs = Observer::new(&mut recorder);
    let r = svc.replay_observed(&trace, &suite, &NoOracle, &mut obs);
    assert_eq!(r.quota_shed, 2);

    let shed = recorder
        .events
        .iter()
        .find(|e| {
            e.kind == "request.admit"
                && e.get("outcome").and_then(|v| v.as_str()) == Some("shed")
                && e.get("reason").and_then(|v| v.as_str()) == Some("quota")
        })
        .expect("a quota shed was recorded");
    let fp = shed.get("fp").and_then(|v| v.as_str()).unwrap().to_string();
    let lines: Vec<Json> = recorder.events.iter().map(|e| e.to_json()).collect();
    let story = explain::explain_events(&lines, &fp);
    assert!(story.contains("SHED: tenant over fair share"), "{story}");
    assert!(story.contains("≥ quota"), "the quota arithmetic is spelled out: {story}");
}

#[test]
fn explain_covers_the_lint_short_circuit_path() {
    let suite = tasks::kernelbench();
    let rtx = gpu::by_key("rtx6000").unwrap();
    // Probe deterministically for a (task, seed) whose round-1 candidate
    // carries a compile-class defect the default gate repairs pre-compile
    // (the bug-injection model is seeded, so the scan is reproducible).
    let mut found = None;
    'outer: for seed in [7u64, 11, 23, 41] {
        let cfg = ServiceConfig {
            threads: 1,
            window: 1,
            seed,
            lint: Some(LintGate::default()),
            ..ServiceConfig::default()
        };
        for i in 0..suite.len() {
            let cand = analysis::round_one_candidate(cfg.coder, &suite[i], rtx, seed);
            if !cand.has_compile_error() {
                continue;
            }
            let r = run_task(&cfg.base_workflow(rtx), &suite[i], &NoOracle);
            if r.lint.checks_saved > 0 {
                found = Some((i, seed));
                break 'outer;
            }
        }
    }
    let (anchor, seed) = found.expect("some (task, seed) short-circuits under the default gate");

    let cfg = ServiceConfig {
        threads: 1,
        window: 1,
        seed,
        lint: Some(LintGate::default()),
        ..ServiceConfig::default()
    };
    let trace = vec![req_at(anchor, "rtx6000", Priority::Standard, 0, 0.0)];
    let mut svc = KernelService::new(cfg.clone());
    let mut recorder = Recorder::default();
    let mut obs = Observer::new(&mut recorder);
    let r = svc.replay_observed(&trace, &suite, &NoOracle, &mut obs);
    assert_eq!(r.lint_short_circuits, 1);
    assert!(recorder.events.iter().any(|e| e.kind == "lint.short_circuit"));

    let fp = cfg.fingerprint_of(&suite[anchor], rtx).to_string();
    let lines: Vec<Json> = recorder.events.iter().map(|e| e.to_json()).collect();
    let story = explain::explain_events(&lines, &fp);
    assert!(story.contains("lint gate repaired the candidate"), "{story}");
    assert!(story.contains("round(s) saved"), "{story}");
}

#[test]
fn profiler_attributes_nearly_all_replay_wall_time() {
    let suite = tasks::kernelbench();
    let trace = generate(
        suite.len(),
        &TrafficConfig { requests: 300, seed: 7, ..TrafficConfig::default() },
    );
    let cfg = ServiceConfig { threads: 2, window: 16, seed: 7, ..ServiceConfig::default() };
    let mut svc = KernelService::new(cfg);
    let mut null = NullSink;
    let mut obs = Observer::new(&mut null);
    obs.profiler = Some(Profiler::new());
    svc.replay_observed(&trace, &suite, &NoOracle, &mut obs);
    let report = obs.profiler.take().unwrap().finish();

    assert!(report.wall_s > 0.0);
    // Self-time stages never double-count: the sum is bounded by the wall.
    assert!(report.stage_sum_s() <= report.wall_s + 1e-6);
    // The acceptance bound: the stage breakdown accounts for (at least)
    // 90% of the profiled span — nothing substantial runs unattributed.
    assert!(
        report.stage_sum_s() >= 0.9 * report.wall_s,
        "stage sum {:.6}s attributes too little of wall {:.6}s",
        report.stage_sum_s(),
        report.wall_s
    );
    // The heavy lifting is the workflow runs (speculative or event-time).
    assert!(report.stage_s(Stage::Workflow) + report.stage_s(Stage::Speculation) > 0.0);
    let rendered = report.table().render();
    assert!(rendered.contains("Replay self-profile"));
    assert!(rendered.contains("total wall"));
}
