//! Integration: the paper's expected shapes hold over the stratified subset
//! D* (DESIGN.md §5, "Expected shapes"). These run the full workflow engine,
//! agents and simulator together — no PJRT required.

#![allow(clippy::disallowed_methods)]

use cudaforge::agents::profiles;
use cudaforge::coordinator::{run_suite, summarize};
use cudaforge::gpu::{A100, H200, RTX3090, RTX6000_ADA};
use cudaforge::tasks::{dstar, kernelbench};
use cudaforge::workflow::{NoOracle, Strategy, WorkflowConfig};

fn wf(strategy: Strategy, seed: u64) -> WorkflowConfig {
    WorkflowConfig::cudaforge(&RTX6000_ADA, seed).with_strategy(strategy)
}

#[test]
fn ablation_ordering_matches_table1() {
    // one-shot << {self-refine, correction-only} < optimization-only <
    // CudaForge; correction-only matches CudaForge on correctness;
    // optimization-only loses correctness.
    let tasks = dstar();
    let t = 8;
    let one = run_suite(&wf(Strategy::OneShot, 2024), &tasks, &NoOracle, t).overall;
    let refine = run_suite(&wf(Strategy::SelfRefine, 2024), &tasks, &NoOracle, t).overall;
    let corr = run_suite(&wf(Strategy::CorrectionOnly, 2024), &tasks, &NoOracle, t).overall;
    let opt = run_suite(&wf(Strategy::OptimizationOnly, 2024), &tasks, &NoOracle, t).overall;
    let cf = run_suite(&wf(Strategy::CudaForge, 2024), &tasks, &NoOracle, t).overall;

    assert!(one.perf < refine.perf, "one-shot {} !< self-refine {}", one.perf, refine.perf);
    assert!(one.perf < corr.perf);
    assert!(corr.perf < cf.perf, "correction {} !< CudaForge {}", corr.perf, cf.perf);
    assert!(refine.perf < cf.perf - 0.1, "self-refine {} !<< CudaForge {}", refine.perf, cf.perf);
    assert!(opt.perf < cf.perf + 0.05);
    assert!(cf.correct >= corr.correct - 0.05, "correction-only correctness parity");
    assert!(opt.correct < corr.correct, "optimization-only must lose correctness");
    assert!(one.correct < 0.75 && cf.correct > 0.9);
}

#[test]
fn full_metrics_underperforms_subset() {
    // 25 tasks is noisy for a single seed; compare seed-averaged means (the
    // paper's D* gap is 1.414 vs 1.767).
    let tasks = dstar();
    let mean_of = |s: Strategy| -> (f64, f64, f64) {
        let mut perf = 0.0;
        let mut usd = 0.0;
        let mut min = 0.0;
        for seed in [11u64, 99, 2024] {
            let o = run_suite(&wf(s, seed), &tasks, &NoOracle, 8).overall;
            perf += o.perf;
            usd += o.avg_cost_usd;
            min += o.avg_time_min;
        }
        (perf / 3.0, usd / 3.0, min / 3.0)
    };
    let (sub_perf, sub_usd, sub_min) = mean_of(Strategy::CudaForge);
    let (full_perf, full_usd, full_min) = mean_of(Strategy::CudaForgeFullMetrics);
    assert!(
        full_perf < sub_perf,
        "full metrics {full_perf} should underperform subset {sub_perf}"
    );
    assert!(full_usd > sub_usd * 1.8, "full metrics must cost more");
    assert!(full_min > sub_min * 1.2);
}

#[test]
fn scaling_rounds_improves_then_saturates() {
    // Fig. 7: steep 1 -> 10, diminishing 10 -> 30.
    let tasks = dstar();
    let perf_at = |n: usize| {
        run_suite(
            &wf(Strategy::CudaForge, 2024).with_rounds(n),
            &tasks,
            &NoOracle,
            8,
        )
        .overall
        .perf
    };
    let p1 = perf_at(1);
    let p10 = perf_at(10);
    let p30 = perf_at(30);
    assert!(p10 > p1 * 1.5, "steep early gains: {p1} -> {p10}");
    assert!(p30 > p10 * 0.98, "late rounds don't regress: {p10} -> {p30}");
    let early_rate = (p10 - p1) / 9.0;
    let late_rate = (p30 - p10) / 20.0;
    assert!(late_rate < early_rate, "diminishing returns: {early_rate} vs {late_rate}");
}

#[test]
fn kevin_loses_to_cudaforge_on_h200() {
    // Fig. 5 shape: CudaForge beats the RL refiner on correctness and perf.
    let tasks = dstar();
    let mk = |s| WorkflowConfig::cudaforge(&H200, 2024).with_strategy(s);
    let cf = run_suite(&mk(Strategy::CudaForge), &tasks, &NoOracle, 8).overall;
    let kevin = run_suite(&mk(Strategy::Kevin), &tasks, &NoOracle, 8).overall;
    assert!(cf.perf > kevin.perf, "CudaForge {} vs Kevin {}", cf.perf, kevin.perf);
    assert!(cf.correct >= kevin.correct);
}

#[test]
fn agentic_baseline_costs_more_and_performs_worse() {
    // Table 1 + Table 3 shape.
    let tasks = dstar();
    let cf = run_suite(&wf(Strategy::CudaForge, 2024), &tasks, &NoOracle, 8).overall;
    let ag = run_suite(&wf(Strategy::AgenticBaseline, 2024), &tasks, &NoOracle, 8).overall;
    assert!(
        ag.avg_cost_usd > cf.avg_cost_usd * 4.0,
        "agentic ${} vs cf ${}",
        ag.avg_cost_usd,
        cf.avg_cost_usd
    );
    assert!(ag.avg_time_min > cf.avg_time_min * 1.5);
    assert!(cf.perf > ag.perf, "CudaForge {} vs agentic {}", cf.perf, ag.perf);
}

#[test]
fn per_level_speedups_have_table2_shape() {
    // L2 > L1 >= L3 in mean speedup; L3 hovers above 1x.
    let tasks = kernelbench();
    let out = run_suite(&wf(Strategy::CudaForge, 2024), &tasks, &NoOracle, 8);
    let perf = |lvl: u8| {
        out.per_level
            .iter()
            .find(|(l, _)| *l == lvl)
            .map(|(_, s)| s.perf)
            .unwrap()
    };
    let (l1, l2, l3) = (perf(1), perf(2), perf(3));
    assert!(l2 > l1, "L2 {l2} should lead L1 {l1}");
    assert!(l2 > l3, "L2 {l2} should lead L3 {l3}");
    assert!(l3 > 0.95, "L3 {l3} should hover above 1x");
    assert!(out.overall.correct > 0.9);
}

#[test]
fn gpu_generalization_table4_shape() {
    // High correctness everywhere (the hardware feedback adapts per target).
    let tasks = dstar();
    let run = |gpu| {
        run_suite(&WorkflowConfig::cudaforge(gpu, 2024), &tasks, &NoOracle, 8).overall
    };
    let r6000 = run(&RTX6000_ADA);
    let a100 = run(&A100);
    let r3090 = run(&RTX3090);
    for (name, s) in [("rtx6000", &r6000), ("a100", &a100), ("rtx3090", &r3090)] {
        assert!(s.correct > 0.85, "{name} correctness {}", s.correct);
        assert!(s.perf > 1.0, "{name} perf {}", s.perf);
    }
}

#[test]
fn model_matrix_table5_shape() {
    // QwQ as Coder is the weakest combination; judge-side swaps stay strong.
    let tasks = dstar();
    let run = |coder, judge| {
        let mut w = wf(Strategy::CudaForge, 2024);
        w.coder = coder;
        w.judge = judge;
        run_suite(&w, &tasks, &NoOracle, 8).overall
    };
    let o3o3 = run(profiles::O3, profiles::O3);
    let qwq = run(profiles::QWQ_32B, profiles::O3);
    let gpt5_judge = run(profiles::O3, profiles::GPT5);
    assert!(qwq.correct < o3o3.correct, "QwQ coder must lose correctness");
    assert!(qwq.perf < o3o3.perf);
    assert!(gpt5_judge.perf > o3o3.perf * 0.85, "GPT-5 judge stays strong");
}

#[test]
fn cost_and_time_match_table3_scale() {
    let tasks = dstar();
    let cf = run_suite(&wf(Strategy::CudaForge, 2024), &tasks, &NoOracle, 8).overall;
    assert!(
        (0.15..=0.60).contains(&cf.avg_cost_usd),
        "CudaForge cost ${} should be ~$0.30",
        cf.avg_cost_usd
    );
    assert!(
        (18.0..=34.0).contains(&cf.avg_time_min),
        "CudaForge time {} min should be ~26.5",
        cf.avg_time_min
    );
}

#[test]
fn summaries_are_seed_stable_but_seed_sensitive() {
    let tasks = dstar();
    let a = run_suite(&wf(Strategy::CudaForge, 1), &tasks, &NoOracle, 4).overall;
    let b = run_suite(&wf(Strategy::CudaForge, 1), &tasks, &NoOracle, 2).overall;
    assert_eq!(a.perf, b.perf, "thread count must not affect results");
    let c = run_suite(&wf(Strategy::CudaForge, 2), &tasks, &NoOracle, 4).overall;
    assert_ne!(a.perf, c.perf, "different seeds explore different runs");
}

#[test]
fn summarize_handles_edge_cases() {
    let s = summarize("empty", &[]);
    assert_eq!(s.n_tasks, 0);
    assert_eq!(s.perf, 0.0);
    assert_eq!(s.correct, 0.0);
}
