//! Integration: the kernel-optimization service layer end to end — replay
//! determinism across worker counts *and* across the host-side `window`
//! batch size, the dispatch-time causality contract (cache refills and
//! warm-start eligibility land at simulated completion instants), the Zipf
//! cache-economics shape the ROADMAP's multi-user target depends on,
//! queueing-aware latency and per-priority SLOs, warm-start convergence,
//! and snapshot/restore warm restarts.

#![allow(clippy::disallowed_methods)]

use cudaforge::gpu;
use cudaforge::service::cache::ResultCache;
use cudaforge::service::queue::Priority;
use cudaforge::service::traffic::{generate, TrafficConfig, TrafficRequest};
use cudaforge::service::{KernelService, ServiceConfig, ServiceReport};
use cudaforge::tasks;
use cudaforge::workflow::{run_task, NoOracle};

/// A hand-built request at an explicit simulated instant.
fn req_at(
    task_index: usize,
    gpu_key: &str,
    priority: Priority,
    arrival_s: f64,
) -> TrafficRequest {
    TrafficRequest {
        task_index,
        gpu: gpu::by_key(gpu_key).unwrap(),
        priority,
        tenant: 0,
        arrival_s,
    }
}

/// Deterministically pick a task whose cold rtx6000 run caches a usable
/// kernel (correct, speedup > 0) under `config` — the anchor the causality
/// scenarios warm-start from.
fn warm_anchor(config: &ServiceConfig, suite: &[tasks::TaskSpec]) -> usize {
    (0..suite.len())
        .find(|i| {
            let wf = config.base_workflow(gpu::by_key("rtx6000").unwrap());
            let r = run_task(&wf, &suite[*i], &NoOracle);
            r.correct && r.best_speedup > 0.0 && r.best_config.is_some()
        })
        .expect("some task solves cold on rtx6000")
}

fn replay(threads: usize, requests: usize, seed: u64) -> ServiceReport {
    let suite = tasks::kernelbench();
    let trace = generate(
        suite.len(),
        &TrafficConfig { requests, seed, ..TrafficConfig::default() },
    );
    let mut svc = KernelService::new(ServiceConfig {
        threads,
        window: 16,
        seed,
        ..ServiceConfig::default()
    });
    svc.replay(&trace, &suite, &NoOracle)
}

#[test]
fn report_identical_regardless_of_worker_count() {
    // The hard determinism contract: every report field — counters, f64
    // latency percentiles and SLO attainments, dollar sums — is
    // bit-identical whether one OS thread or eight crunch the flights. The
    // simulated fleet (`sim_workers`) is part of the config, not the host,
    // so `threads` changes wall-clock only.
    let a = replay(1, 300, 7);
    let b = replay(2, 300, 7);
    let c = replay(8, 300, 7);
    assert_eq!(a, b);
    assert_eq!(a, c);
    // ...and seeds actually matter.
    let d = replay(2, 300, 8);
    assert_ne!(a, d);
}

#[test]
fn zipf_traffic_amortizes_most_requests() {
    let r = replay(4, 500, 7);
    assert!(r.hit_rate > 0.5, "hit rate {} on Zipf traffic", r.hit_rate);
    assert!(
        (r.flights_run as u64) + r.cache_hits + r.shared + r.rejected == r.requests as u64,
        "admission classes partition the trace"
    );
    assert!(r.api_usd_saved > r.api_usd_spent * 0.5, "cache pays for itself");
    assert!((r.api_usd_cold - r.api_usd_spent - r.api_usd_saved).abs() < 1e-9);
    // Median request is a cache hit (sub-second); tail is a cold run plus
    // whatever it queued behind.
    assert!(r.p50_latency_s < 1.0, "p50 {}", r.p50_latency_s);
    assert!(r.p95_latency_s > 60.0, "p95 {}", r.p95_latency_s);
    assert!(r.p99_latency_s >= r.p95_latency_s);
}

#[test]
fn per_priority_slos_cover_every_class() {
    let r = replay(4, 500, 7);
    assert_eq!(r.per_priority.len(), 3);
    let classes: Vec<Priority> = r.per_priority.iter().map(|c| c.priority).collect();
    assert_eq!(
        classes,
        vec![Priority::Interactive, Priority::Standard, Priority::Batch]
    );
    assert_eq!(
        r.per_priority.iter().map(|c| c.requests).sum::<usize>(),
        r.requests,
        "classes partition the trace"
    );
    for c in &r.per_priority {
        assert!(c.requests > 0, "default mix populates {}", c.priority.name());
        assert!((0.0..=1.0).contains(&c.slo_attainment));
        assert!(c.p50_latency_s <= c.p95_latency_s);
        assert!(c.p95_latency_s <= c.p99_latency_s);
        assert!(c.slo_target_s > 0.0);
    }
    // No admission bound configured: nothing is shed.
    assert_eq!(r.rejected, 0);
    assert!(r.per_priority.iter().all(|c| c.rejected == 0));
}

#[test]
fn smaller_fleets_queue_longer() {
    // The fleet-sizing question the simulator exists to answer: the same
    // traffic on fewer simulated GPUs must show equal-or-worse queue wait
    // and tail latency, monotonically.
    let suite = tasks::kernelbench();
    let trace = generate(
        suite.len(),
        &TrafficConfig { requests: 300, mean_interarrival_s: 20.0, ..TrafficConfig::default() },
    );
    let run = |sim_workers: usize| {
        let mut svc = KernelService::new(ServiceConfig {
            threads: 4,
            window: 16,
            sim_workers,
            ..ServiceConfig::default()
        });
        svc.replay(&trace, &suite, &NoOracle)
    };
    let narrow = run(1);
    let wide = run(64);
    assert!(narrow.mean_queue_wait_s >= wide.mean_queue_wait_s);
    assert!(narrow.p99_latency_s >= wide.p99_latency_s);
    assert!(
        narrow.mean_queue_wait_s > 0.0,
        "300 requests every ~20s must saturate a single simulated GPU"
    );
    // Both fleets answer every request one way or another.
    assert_eq!(
        narrow.cache_hits + narrow.shared + narrow.flights_run as u64 + narrow.rejected,
        narrow.requests as u64
    );
    assert_eq!(
        wide.cache_hits + wide.shared + wide.flights_run as u64 + wide.rejected,
        wide.requests as u64
    );
}

#[test]
fn warm_starts_converge_in_strictly_fewer_mean_rounds() {
    // The acceptance property for the cross-GPU transfer heuristic, at the
    // service level: secondary-GPU requests for tasks already solved on the
    // primary GPU reach their best kernel in fewer rounds than cold runs.
    let r = replay(4, 600, 7);
    assert!(r.warm_started > 0, "trace must trigger cross-GPU warm starts");
    assert!(r.warm_correct > 0, "warm runs must stay correct");
    assert!(r.warm_correct <= r.warm_started);
    assert!(r.mean_rounds_to_best_cold > 0.0);
    assert!(
        r.mean_rounds_to_best_warm < r.mean_rounds_to_best_cold,
        "warm {} !< cold {}",
        r.mean_rounds_to_best_warm,
        r.mean_rounds_to_best_cold
    );
}

#[test]
fn snapshot_restore_makes_the_restart_warm() {
    let suite = tasks::kernelbench();
    let config = ServiceConfig { threads: 2, window: 16, ..ServiceConfig::default() };
    let dir = std::env::temp_dir().join("cudaforge_service_itest");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cache.jsonl");

    let day1 = generate(
        suite.len(),
        &TrafficConfig { requests: 300, seed: 7, ..TrafficConfig::default() },
    );
    let mut svc = KernelService::new(config.clone());
    let r1 = svc.replay(&day1, &suite, &NoOracle);
    svc.cache().snapshot(&path).unwrap();

    // Same traffic, fresh process, restored cache: nothing needs a rerun
    // except the never-correct stragglers.
    let cache = ResultCache::restore(&path, config.capacity).unwrap();
    assert_eq!(cache.len(), svc.cache().len());
    let mut warm = KernelService::with_cache(config.clone(), cache);
    let r2 = warm.replay(&day1, &suite, &NoOracle);
    assert!(
        r2.hit_rate > r1.hit_rate,
        "restored cache must beat the cold start: {} vs {}",
        r2.hit_rate,
        r1.hit_rate
    );
    assert!(r2.api_usd_spent < r1.api_usd_spent);
    assert!(r2.flights_run < r1.flights_run);

    // Restoring into a smaller cache is a real capacity decision: the
    // forced evictions are recorded, the hottest entries survive.
    if svc.cache().len() > 2 {
        let shrunk = ResultCache::restore(&path, 2).unwrap();
        assert_eq!(shrunk.len(), 2);
        assert_eq!(
            shrunk.stats.evictions as usize,
            svc.cache().len() - 2,
            "squeezing {} entries into 2 must evict the rest",
            svc.cache().len()
        );
    }

    // A cold-restarted service on the same trace reproduces day 1 exactly —
    // the snapshot is what made the difference.
    let mut cold = KernelService::new(config);
    let r3 = cold.replay(&day1, &suite, &NoOracle);
    assert_eq!(r1, r3);
}

#[test]
fn cluster_shard_files_are_single_node_snapshots() {
    // Cross-layer compat contract: each `shard-<i>.jsonl` a cluster
    // snapshot writes is a valid single-node cache snapshot (the epoch /
    // shard / nodes stamps ride in the header, which `ResultCache::restore`
    // ignores) — an operator can lift one shard out of a cluster snapshot
    // and warm a single-node service with it.
    use cudaforge::cluster::{ClusterConfig, ClusterService};
    let suite = tasks::kernelbench();
    let trace = generate(
        suite.len(),
        &TrafficConfig { requests: 120, seed: 7, ..TrafficConfig::default() },
    );
    let mut cluster = ClusterService::new(ClusterConfig {
        nodes: 2,
        service: ServiceConfig { threads: 2, window: 16, seed: 7, ..ServiceConfig::default() },
        ..ClusterConfig::default()
    });
    cluster.replay(&trace, &suite, &NoOracle);
    let dir = std::env::temp_dir().join("cudaforge_shard_compat_itest");
    let _ = std::fs::remove_dir_all(&dir);
    let manifest = cluster.snapshot(&dir).unwrap();

    for (i, shard) in manifest.shards.iter().enumerate() {
        let restored = ResultCache::restore(dir.join(&shard.file), 1024).unwrap();
        assert_eq!(restored.len(), cluster.cache(i).len(), "shard {i} round-trips");
        for e in cluster.cache(i).entries_coldest_first() {
            assert_eq!(restored.peek(e.fingerprint), Some(e), "shard {i} entry survives");
        }
    }
}

#[test]
fn window_batch_size_never_changes_the_report() {
    // `window` is demoted to a host-side OS-thread batching knob: the
    // replay is event-driven, so the full report — counters, latency
    // percentiles, dollar sums — is bit-identical whether speculation runs
    // one arrival at a time or sixty-four.
    let suite = tasks::kernelbench();
    let trace = generate(
        suite.len(),
        &TrafficConfig { requests: 300, seed: 7, ..TrafficConfig::default() },
    );
    let run = |window: usize| {
        let mut svc = KernelService::new(ServiceConfig {
            threads: 2,
            window,
            seed: 7,
            ..ServiceConfig::default()
        });
        svc.replay(&trace, &suite, &NoOracle)
    };
    let a = run(1);
    let b = run(4);
    let c = run(64);
    assert_eq!(a, b);
    assert_eq!(a, c);
}

#[test]
fn fast_early_flight_warm_starts_a_later_same_window_arrival() {
    // Both requests land in one admission window, but the rtx6000 flight
    // starts at t = 0 and completes long before the a100 request arrives —
    // so the a100 run must warm-start from it. The old window-batched
    // dispatch prepared every flight in the window before any of them ran,
    // which made this warm start impossible; this is the regression test
    // for that artifact.
    let suite = tasks::kernelbench();
    let config = ServiceConfig { threads: 1, window: 16, ..ServiceConfig::default() };
    let anchor = warm_anchor(&config, &suite);
    let trace = vec![
        req_at(anchor, "rtx6000", Priority::Standard, 0.0),
        req_at(anchor, "a100", Priority::Standard, 500_000.0),
    ];
    let mut svc = KernelService::new(config);
    let r = svc.replay(&trace, &suite, &NoOracle);
    assert_eq!(r.flights_run, 2);
    assert_eq!(
        r.warm_started, 1,
        "the same-window a100 run must seed from the completed rtx6000 flight"
    );
}

#[test]
fn no_warm_start_from_a_still_running_flight() {
    // The a100 request arrives one simulated second after the rtx6000
    // flight opened — roughly half an hour before that flight completes.
    // With `window: 1` the old code had already inserted the rtx6000 cache
    // entry at its window's dispatch and warm-started from the future; the
    // event-driven replay must run the a100 flight cold.
    let suite = tasks::kernelbench();
    let config = ServiceConfig {
        threads: 1,
        window: 1,
        sim_workers: 8,
        ..ServiceConfig::default()
    };
    let anchor = warm_anchor(&config, &suite);
    let trace = vec![
        req_at(anchor, "rtx6000", Priority::Standard, 0.0),
        req_at(anchor, "a100", Priority::Standard, 1.0),
    ];
    let mut svc = KernelService::new(config);
    let r = svc.replay(&trace, &suite, &NoOracle);
    assert_eq!(r.flights_run, 2);
    assert_eq!(
        r.warm_started, 0,
        "the rtx6000 result does not exist yet at the a100 flight's start"
    );
}

#[test]
fn causality_assertions_hold_across_seeds() {
    // The replay is assertion-instrumented: every warm start's seed and
    // every cache hit's entry must come from a flight that completed by the
    // consumer's start/arrival (debug_asserts over a per-replay
    // completion-instant audit map). Replaying several seeds — plus a
    // second day over the now-warm cache, whose restored entries are
    // visible from t = 0 — exercises those assertions end to end; any
    // violation panics this test.
    let suite = tasks::kernelbench();
    for seed in [7u64, 11, 23] {
        let trace = generate(
            suite.len(),
            &TrafficConfig { requests: 250, seed, ..TrafficConfig::default() },
        );
        let mut svc = KernelService::new(ServiceConfig {
            threads: 2,
            window: 8,
            sim_workers: 2,
            seed,
            ..ServiceConfig::default()
        });
        let r1 = svc.replay(&trace, &suite, &NoOracle);
        assert_eq!(
            r1.cache_hits + r1.shared + r1.flights_run as u64 + r1.rejected,
            r1.requests as u64
        );
        let day2 = generate(
            suite.len(),
            &TrafficConfig { requests: 100, seed: seed + 1, ..TrafficConfig::default() },
        );
        let r2 = svc.replay(&day2, &suite, &NoOracle);
        assert_eq!(
            r2.cache_hits + r2.shared + r2.flights_run as u64 + r2.rejected,
            r2.requests as u64
        );
    }
}
