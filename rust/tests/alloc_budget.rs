//! Allocation-budget regression fence for the replay hot path.
//!
//! The PR-9 overhaul (interned fingerprints, the SoA flight arena, the
//! scratch-buffer report) is only worth keeping if it *stays* kept: a
//! future change that quietly reintroduces a per-request `clone()` or a
//! per-event `format!` would still pass every behavioural test. This
//! binary installs [`CountingAlloc`] as the global allocator and holds an
//! untraced mid-size replay to a stated allocations-per-request budget.
//!
//! The budgets are deliberately generous — they are tripwires for
//! order-of-magnitude regressions, not byte-exact accounting:
//!
//! - **cold** (empty cache, every distinct fingerprint runs a workflow):
//!   20 000 allocations/request, dominated by the workflow runs
//!   themselves, not the admission loop;
//! - **warm** (second replay of the same trace on the same service, all
//!   cache hits): 64 allocations/request — the admission loop proper
//!   (intern + probe + hit accounting + report) allocates almost nothing,
//!   so even a small per-request leak trips this fence.
//!
//! Kept as its own test binary: the counter is process-global, so a
//! sibling test allocating on another thread would pollute the figures.

#![allow(clippy::disallowed_methods)]

use cudaforge::service::traffic::{generate, TrafficConfig};
use cudaforge::service::{KernelService, ServiceConfig};
use cudaforge::tasks;
use cudaforge::util::bench::{allocations, CountingAlloc};
use cudaforge::workflow::NoOracle;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

const REQUESTS: usize = 2000;
const COLD_BUDGET_PER_REQ: u64 = 20_000;
const WARM_BUDGET_PER_REQ: u64 = 64;

#[test]
fn replay_stays_within_allocation_budget() {
    let suite = tasks::kernelbench();
    let trace = generate(
        suite.len(),
        &TrafficConfig { requests: REQUESTS, seed: 11, ..TrafficConfig::default() },
    );
    let mut svc = KernelService::new(ServiceConfig {
        threads: 1,
        window: 16,
        seed: 11,
        ..ServiceConfig::default()
    });

    // Cold pass: misses run full workflows, so the budget is loose.
    let before_cold = allocations();
    let cold = svc.replay(&trace, &suite, &NoOracle);
    let cold_allocs = allocations() - before_cold;
    assert_eq!(cold.requests, REQUESTS);
    assert!(
        cold_allocs <= COLD_BUDGET_PER_REQ * REQUESTS as u64,
        "cold replay allocated {cold_allocs} times for {REQUESTS} requests \
         (budget {COLD_BUDGET_PER_REQ}/request)"
    );

    // Warm pass: the same trace against the now-populated cache exercises
    // the admission hot path alone — intern, probe, hit, report.
    let before_warm = allocations();
    let warm = svc.replay(&trace, &suite, &NoOracle);
    let warm_allocs = allocations() - before_warm;
    assert_eq!(warm.requests, REQUESTS);
    assert!(
        warm.cache_hits > REQUESTS / 2,
        "warm replay should be hit-dominated, saw {} hits",
        warm.cache_hits
    );
    assert!(
        warm_allocs <= WARM_BUDGET_PER_REQ * REQUESTS as u64,
        "warm replay allocated {warm_allocs} times for {REQUESTS} requests \
         (budget {WARM_BUDGET_PER_REQ}/request) — a per-request allocation \
         crept back into the hot path"
    );
}
