//! Integration: the sharded multi-tenant cluster layer — the determinism
//! contracts (a 1-node single-tenant cluster is bit-identical to the
//! single-node service, and cluster reports are bit-identical across OS
//! thread counts *and* across the host-side `window` batch size), plus the
//! cluster-only behaviours: membership events (node failure, node join
//! with planned rebalance) and their accounting, shard-aware snapshot
//! save/restore round-trips (bit-identical under unchanged membership,
//! exactly-accounted movement under a changed node count), fair-share
//! tenant quotas under overload, and cross-node warm-start routing with
//! its transfer latency — all on the global event loop, where a warm seed
//! must come from a flight already completed (or a transfer already
//! landed) in simulated time.

#![allow(clippy::disallowed_methods)]

use cudaforge::cluster::{
    ClusterConfig, ClusterReport, ClusterService, MembershipEvent, RebalanceKind, Router,
    TenantSpec,
};
use cudaforge::gpu;
use cudaforge::service::queue::Priority;
use cudaforge::service::traffic::{generate, TrafficConfig, TrafficRequest};
use cudaforge::service::{KernelService, ServiceConfig};
use cudaforge::tasks;
use cudaforge::workflow::{run_task, NoOracle};

/// A hand-built request at an explicit simulated instant.
fn req_at(
    task_index: usize,
    gpu_key: &str,
    priority: Priority,
    tenant: usize,
    arrival_s: f64,
) -> TrafficRequest {
    TrafficRequest {
        task_index,
        gpu: gpu::by_key(gpu_key).unwrap(),
        priority,
        tenant,
        arrival_s,
    }
}

#[test]
fn one_node_single_tenant_cluster_is_bit_identical_to_the_service() {
    let suite = tasks::kernelbench();
    let trace = generate(
        suite.len(),
        &TrafficConfig { requests: 300, seed: 7, ..TrafficConfig::default() },
    );
    let service_cfg = ServiceConfig { threads: 2, window: 16, seed: 7, ..ServiceConfig::default() };

    let mut single = KernelService::new(service_cfg.clone());
    let expected = single.replay(&trace, &suite, &NoOracle);

    let mut cluster = ClusterService::new(ClusterConfig {
        service: service_cfg,
        nodes: 1,
        ..ClusterConfig::default()
    });
    let r = cluster.replay(&trace, &suite, &NoOracle);
    // The hard contract: every aggregate — counters, f64 percentiles,
    // dollar sums — is the single-node report, bit for bit.
    assert_eq!(r.overall, expected);
    assert_eq!(r.nodes, 1);
    assert_eq!(r.per_node.len(), 1);
    assert_eq!(r.per_node[0].requests, expected.requests);
    assert_eq!(r.per_node[0].cache_hits, expected.cache_hits);
    assert_eq!(r.per_node[0].flights_run, expected.flights_run);
    assert_eq!(r.cross_node_warm, 0, "one node has no other shard to fetch from");
    assert_eq!(r.quota_shed, 0);

    // Same contract on the overload path: a bounded queue shedding batch
    // work must shed identically through the cluster's admission.
    let burst: Vec<TrafficRequest> = (0..12)
        .map(|i| {
            let p = if i % 4 == 3 { Priority::Interactive } else { Priority::Batch };
            req_at(i, "rtx6000", p, 0, i as f64)
        })
        .collect();
    let tight = ServiceConfig {
        threads: 1,
        window: 4,
        sim_workers: 1,
        queue_depth: 2,
        seed: 7,
        ..ServiceConfig::default()
    };
    let mut single = KernelService::new(tight.clone());
    let expected = single.replay(&burst, &suite, &NoOracle);
    assert!(expected.rejected > 0, "the burst must overload the bounded queue");
    let mut cluster = ClusterService::new(ClusterConfig {
        service: tight,
        nodes: 1,
        ..ClusterConfig::default()
    });
    assert_eq!(cluster.replay(&burst, &suite, &NoOracle).overall, expected);
}

fn sharded_replay(threads: usize, seed: u64, window: usize) -> ClusterReport {
    let suite = tasks::kernelbench();
    let trace = generate(
        suite.len(),
        &TrafficConfig {
            requests: 300,
            seed,
            tenant_mix: vec![("alpha".to_string(), 3.0), ("beta".to_string(), 1.0)],
            ..TrafficConfig::default()
        },
    );
    // Exercise every cluster feature at once: sharding, quotas, a
    // mid-replay node failure *and recovery* (the node rejoins empty,
    // triggering a planned rebalance with in-transit refills), a locality
    // margin on cross-node warm transfers.
    let fail_at = trace[trace.len() / 2].arrival_s;
    let rejoin_at = trace[3 * trace.len() / 4].arrival_s;
    let mut svc = ClusterService::new(ClusterConfig {
        nodes: 3,
        tenants: vec![TenantSpec::new("alpha", 3.0), TenantSpec::new("beta", 1.0)],
        tenant_quotas: true,
        transfer_latency_s: 30.0,
        warm_locality_margin: 0.25,
        events: vec![
            MembershipEvent::fail(1, fail_at),
            MembershipEvent::join(1, rejoin_at),
        ],
        service: ServiceConfig {
            threads,
            window,
            sim_workers: 2,
            queue_depth: 8,
            seed,
            ..ServiceConfig::default()
        },
        ..ClusterConfig::default()
    });
    svc.replay(&trace, &suite, &NoOracle)
}

#[test]
fn cluster_report_identical_regardless_of_worker_count() {
    // The existing single-node assertion, extended to the cluster: the full
    // ClusterReport — per-node, per-tenant, and rebalance views included —
    // is bit-identical whether 1, 2, or 8 OS threads crunch the flights.
    let a = sharded_replay(1, 7, 16);
    let b = sharded_replay(2, 7, 16);
    let c = sharded_replay(8, 7, 16);
    assert_eq!(a, b);
    assert_eq!(a, c);
    // ...and seeds actually matter.
    let d = sharded_replay(2, 8, 16);
    assert_ne!(a, d);
}

#[test]
fn cluster_window_batch_size_never_changes_the_report() {
    // `window` only batches the host-side speculative runs; the cluster's
    // global event loop is window-free. Replaying the full feature mix
    // (sharding + quotas + failure + cross-node warms) over several seeds
    // also drives the causality debug_asserts — every warm seed's producing
    // flight completed by its consumer's start, on every node.
    for seed in [7u64, 11, 23] {
        let a = sharded_replay(2, seed, 1);
        let b = sharded_replay(2, seed, 64);
        assert_eq!(a, b, "seed {seed}: window 1 vs 64 must be bit-identical");
    }
}

#[test]
fn node_failure_rehashes_keys_and_accounts_the_re_miss() {
    let suite = tasks::kernelbench();
    let probe_cfg = ServiceConfig { threads: 1, window: 1, seed: 7, ..ServiceConfig::default() };
    // Deterministically pick a task whose cold rtx6000 run caches a usable
    // kernel, so the shard provably holds its key when the node dies.
    let anchor = (0..suite.len())
        .find(|i| {
            let wf = probe_cfg.base_workflow(gpu::by_key("rtx6000").unwrap());
            let r = run_task(&wf, &suite[*i], &NoOracle);
            r.correct && r.best_speedup > 0.0 && r.best_config.is_some()
        })
        .expect("some task solves cold on rtx6000");
    let fp = probe_cfg.fingerprint_of(&suite[anchor], gpu::by_key("rtx6000").unwrap());
    let owner = Router::new(2).route(fp, &[true, true]).unwrap();

    // Arrivals are spaced far beyond any run's simulated service time, so
    // the repeat at t=100k is a true cache hit (not an in-flight join).
    let trace = vec![
        req_at(anchor, "rtx6000", Priority::Standard, 0, 0.0),
        req_at(anchor, "rtx6000", Priority::Standard, 0, 100_000.0),
        req_at(anchor, "rtx6000", Priority::Standard, 0, 200_000.0),
    ];
    let mut svc = ClusterService::new(ClusterConfig {
        nodes: 2,
        events: vec![MembershipEvent::fail(owner, 150_000.0)],
        service: probe_cfg,
        ..ClusterConfig::default()
    });
    let r = svc.replay(&trace, &suite, &NoOracle);
    // t=0 runs cold and caches on `owner`; t=100k hits that shard; at
    // t=150k the shard dies; t=200k rehashes to the survivor and re-runs.
    assert_eq!(r.overall.flights_run, 2, "the lost key re-misses");
    assert_eq!(r.overall.cache_hits, 1);
    assert_eq!(r.rebalances.len(), 1, "failure fired mid-replay");
    let rb = &r.rebalances[0];
    assert_eq!(rb.kind, RebalanceKind::NodeFailure);
    assert_eq!(rb.node, owner);
    assert!(rb.cache_entries_lost >= 1, "the anchor entry was resident");
    assert!(rb.rehashed_requests >= 1, "the t=200 request was displaced");
    assert_eq!(rb.remissed_flights, 1);
    assert!(rb.remiss_api_usd > 0.0, "the re-run re-spent API dollars");
    assert_eq!(r.epoch, 1, "one membership change applied");
    assert!(!r.per_node[owner].alive);
    assert!(r.per_node[1 - owner].alive);
    // The survivor ran the re-miss.
    assert!(r.per_node[1 - owner].flights_run >= 1);
}

#[test]
fn node_join_warm_refills_rehashed_keys_and_prices_the_gap() {
    let suite = tasks::kernelbench();
    let probe_cfg = ServiceConfig { threads: 1, window: 1, seed: 7, ..ServiceConfig::default() };
    let anchor = (0..suite.len())
        .find(|i| {
            let wf = probe_cfg.base_workflow(gpu::by_key("rtx6000").unwrap());
            let r = run_task(&wf, &suite[*i], &NoOracle);
            r.correct && r.best_speedup > 0.0 && r.best_config.is_some()
        })
        .expect("some task solves cold on rtx6000");
    let fp = probe_cfg.fingerprint_of(&suite[anchor], gpu::by_key("rtx6000").unwrap());
    // The node that owns the anchor under full membership is the joiner: it
    // starts outside the cluster (its first event is a join), so the anchor
    // initially lands on the survivor.
    let joiner = Router::new(2).route(fp, &[true, true]).unwrap();
    let survivor = 1 - joiner;
    let transfer = 5_000.0;
    let mk = |cfg: &ServiceConfig| ClusterConfig {
        nodes: 2,
        transfer_latency_s: transfer,
        events: vec![MembershipEvent::join(joiner, 150_000.0)],
        service: cfg.clone(),
        ..ClusterConfig::default()
    };

    // ---- the clean rebalance: no request lands inside the transfer gap --
    // t=0 cold on the survivor; t=100k hits the survivor; the join at
    // t=150k moves the key, landing at t=155k; t=200k hits the *joiner*.
    let trace = vec![
        req_at(anchor, "rtx6000", Priority::Standard, 0, 0.0),
        req_at(anchor, "rtx6000", Priority::Standard, 0, 100_000.0),
        req_at(anchor, "rtx6000", Priority::Standard, 0, 200_000.0),
    ];
    let mut svc = ClusterService::new(mk(&probe_cfg));
    assert!(!svc.membership().is_alive(joiner), "the joiner starts outside");
    let r = svc.replay(&trace, &suite, &NoOracle);
    assert_eq!(r.overall.flights_run, 1, "the moved key never re-runs");
    assert_eq!(r.overall.cache_hits, 2, "a hit on each side of the join");
    assert_eq!(r.epoch, 1);
    assert_eq!(r.rebalances.len(), 1);
    let rb = &r.rebalances[0];
    assert_eq!(rb.kind, RebalanceKind::NodeJoin);
    assert_eq!(rb.node, joiner);
    assert_eq!(rb.at_s, 150_000.0);
    assert_eq!(rb.entries_moved, 1, "exactly the anchor's entry moves");
    assert!((rb.transfer_s - transfer).abs() < 1e-9, "transfer spend itemized");
    assert_eq!(rb.cache_entries_lost, 0);
    assert_eq!(rb.remissed_flights, 0, "nothing arrived inside the gap");
    assert_eq!(rb.rehashed_requests, 1, "the t=200k request now routes to the joiner");
    assert!(r.per_node[joiner].alive && r.per_node[survivor].alive);
    // The entry genuinely moved shards.
    assert!(svc.cache(joiner).peek(fp).is_some(), "refill landed on the joiner");
    assert!(svc.cache(survivor).peek(fp).is_none(), "the survivor handed it off");

    // ---- the gap re-miss: a request between join and landing re-runs ----
    let gap_trace = vec![
        req_at(anchor, "rtx6000", Priority::Standard, 0, 0.0),
        req_at(anchor, "rtx6000", Priority::Standard, 0, 100_000.0),
        req_at(anchor, "rtx6000", Priority::Standard, 0, 152_000.0),
        req_at(anchor, "rtx6000", Priority::Standard, 0, 200_000.0),
    ];
    let mut svc = ClusterService::new(mk(&probe_cfg));
    let r = svc.replay(&gap_trace, &suite, &NoOracle);
    assert_eq!(
        r.overall.flights_run, 2,
        "the in-transit key re-runs for the gap arrival"
    );
    assert_eq!(r.overall.cache_hits, 2);
    let rb = &r.rebalances[0];
    assert_eq!(rb.entries_moved, 1);
    assert_eq!(rb.remissed_flights, 1, "the gap arrival is the join's re-miss");
    assert!(rb.remiss_api_usd > 0.0);
    assert_eq!(rb.rehashed_requests, 2, "both post-join arrivals route to the joiner");
}

/// Temp dir helper: a fresh, empty snapshot directory per test.
fn fresh_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn cluster_snapshot_round_trip_is_bit_identical_under_unchanged_membership() {
    let suite = tasks::kernelbench();
    let mk_trace = |seed: u64| {
        generate(
            suite.len(),
            &TrafficConfig {
                requests: 150,
                seed,
                tenant_mix: vec![("a".to_string(), 3.0), ("b".to_string(), 1.0)],
                ..TrafficConfig::default()
            },
        )
    };
    let warm_trace = mk_trace(7);
    let day2 = mk_trace(11);
    let cfg = ClusterConfig {
        nodes: 3,
        tenants: vec![TenantSpec::new("a", 3.0), TenantSpec::new("b", 1.0)],
        service: ServiceConfig { threads: 2, window: 16, seed: 7, ..ServiceConfig::default() },
        ..ClusterConfig::default()
    };
    let dir = fresh_dir("cudaforge_cluster_snap_roundtrip");

    let mut original = ClusterService::new(cfg.clone());
    original.replay(&warm_trace, &suite, &NoOracle);
    let manifest = original.snapshot(&dir).unwrap();
    assert_eq!(manifest.nodes, 3);
    assert!(
        manifest.shards.iter().map(|s| s.entries).sum::<usize>() > 0,
        "the warm replay cached something"
    );

    let (mut restored, rb) = ClusterService::restore(cfg, &dir).unwrap();
    assert!(rb.is_none(), "unchanged membership: nothing moves");
    assert_eq!(restored.epoch(), original.epoch());
    for n in 0..3 {
        assert_eq!(restored.cache(n).len(), original.cache(n).len());
    }

    // The hard contract: day-2 traffic replays bit-identically through the
    // snapshot-restored cluster and the original warm one — every counter,
    // percentile, and dollar sum (the snapshot carries per-shard recency
    // *and* the cluster-wide cold-cost registry, so counterfactual pricing
    // survives the restart too).
    let expected = original.replay(&day2, &suite, &NoOracle);
    let got = restored.replay(&day2, &suite, &NoOracle);
    assert_eq!(got, expected);
    assert!(expected.overall.cache_hits > 0, "day 2 re-uses day 1's work");
}

#[test]
fn restore_under_a_different_node_count_accounts_the_movement_exactly() {
    let suite = tasks::kernelbench();
    let trace = generate(
        suite.len(),
        &TrafficConfig { requests: 200, seed: 7, ..TrafficConfig::default() },
    );
    let mk = |nodes: usize| ClusterConfig {
        nodes,
        service: ServiceConfig { threads: 2, window: 16, seed: 7, ..ServiceConfig::default() },
        ..ClusterConfig::default()
    };
    let dir = fresh_dir("cudaforge_cluster_snap_regrow");
    let mut two = ClusterService::new(mk(2));
    two.replay(&trace, &suite, &NoOracle);
    two.snapshot(&dir).unwrap();
    let entries_before: usize = (0..2).map(|n| two.cache(n).len()).sum();
    assert!(entries_before > 0);

    // Expected movement under the grown router, computed independently.
    let r3 = Router::new(3);
    let alive3 = [true, true, true];
    let expected_moved: usize = (0..2)
        .map(|n| {
            two.cache(n)
                .entries_coldest_first()
                .filter(|e| r3.route(e.fingerprint, &alive3) != Some(n))
                .count()
        })
        .sum();
    assert!(expected_moved > 0, "growing 2 -> 3 must displace some keys");

    let (mut three, rb) = ClusterService::restore(mk(3), &dir).unwrap();
    let rb = rb.expect("a node-count change is a rebalance");
    assert_eq!(rb.kind, RebalanceKind::SnapshotRestore);
    assert_eq!(rb.node, 2, "the snapshot was laid out for 2 nodes");
    assert_eq!(rb.entries_moved, expected_moved, "movement is exactly accounted");
    assert_eq!(rb.cache_entries_lost, 0);
    assert!((rb.transfer_s - expected_moved as f64 * 30.0).abs() < 1e-9);
    assert_eq!(three.epoch(), two.epoch() + 1, "the regrow is a membership change");
    // Conservation: every entry landed, and on its 3-node owner.
    let entries_after: usize = (0..3).map(|n| three.cache(n).len()).sum();
    assert_eq!(entries_after, entries_before);
    for n in 0..3 {
        for e in three.cache(n).entries_coldest_first() {
            assert_eq!(r3.route(e.fingerprint, &alive3), Some(n));
        }
    }
    // The restore's movement also leads the next replay's report, so a
    // library caller reading ClusterReport.rebalances sees it too.
    let r = three.replay(&trace, &suite, &NoOracle);
    assert_eq!(
        r.rebalances.first().map(|rb| (rb.kind, rb.entries_moved)),
        Some((RebalanceKind::SnapshotRestore, expected_moved)),
        "the restore rebalance rides into the first post-restore replay"
    );

    // Shrinking 2 -> 1 is the inverse: exactly shard 1's entries move.
    let (one, rb) = ClusterService::restore(mk(1), &dir).unwrap();
    let rb = rb.expect("a node-count change is a rebalance");
    assert_eq!(rb.entries_moved, two.cache(1).len());
    assert_eq!(one.cache(0).len(), entries_before);
}

#[test]
fn fair_share_quotas_shed_the_hog_and_protect_the_light_tenant() {
    let suite = tasks::kernelbench();
    // One node, queue_depth 4, equal weights => 2 backlog slots per tenant.
    // Tenant 0 bursts 6 distinct standard-priority requests; tenant 1 sends
    // 2. Nothing is batch, so only the quota knob can shed.
    let mut trace: Vec<TrafficRequest> = (0..6)
        .map(|i| req_at(i, "rtx6000", Priority::Standard, 0, 0.0))
        .collect();
    trace.push(req_at(6, "rtx6000", Priority::Standard, 1, 0.0));
    trace.push(req_at(7, "rtx6000", Priority::Standard, 1, 0.0));
    let mk = |quotas: bool| ClusterConfig {
        nodes: 1,
        tenants: vec![TenantSpec::new("hog", 1.0), TenantSpec::new("light", 1.0)],
        tenant_quotas: quotas,
        service: ServiceConfig {
            threads: 1,
            window: 32,
            sim_workers: 1,
            queue_depth: 4,
            seed: 7,
            ..ServiceConfig::default()
        },
        ..ClusterConfig::default()
    };
    let mut svc = ClusterService::new(mk(true));
    let r = svc.replay(&trace, &suite, &NoOracle);
    assert_eq!(r.quota_shed, 2, "the hog's 5th and 6th opens exceed its share");
    assert_eq!(r.per_tenant[0].quota_shed, 2);
    assert_eq!(r.per_tenant[0].rejected, 2);
    assert_eq!(
        r.per_tenant[1].quota_shed, 0,
        "the light tenant is admitted past the bound — that is the fair share"
    );
    assert_eq!(r.per_tenant[1].rejected, 0);
    assert_eq!(r.overall.flights_run, 6);
    assert_eq!(
        r.overall.cache_hits + r.overall.shared + r.overall.flights_run as u64
            + r.overall.rejected,
        r.overall.requests as u64
    );

    // Quotas off: standard-priority work is never shed (the pre-cluster
    // behaviour), so the hog monopolizes the backlog unchecked.
    let mut open = ClusterService::new(mk(false));
    let r = open.replay(&trace, &suite, &NoOracle);
    assert_eq!(r.overall.rejected, 0);
    assert_eq!(r.quota_shed, 0);
    assert_eq!(r.overall.flights_run, 8);
}

#[test]
fn cross_node_warm_starts_pay_the_transfer_latency() {
    let suite = tasks::kernelbench();
    let probe_cfg = ServiceConfig { threads: 1, window: 1, seed: 7, ..ServiceConfig::default() };
    let router = Router::new(2);
    let alive = [true, true];
    let rtx = gpu::by_key("rtx6000").unwrap();
    // Find a task that (a) caches a usable kernel cold on rtx6000 and
    // (b) has a second GPU whose fingerprint shards onto the *other* node.
    let mut found = None;
    'outer: for i in 0..suite.len() {
        let r = run_task(&probe_cfg.base_workflow(rtx), &suite[i], &NoOracle);
        if !(r.correct && r.best_speedup > 0.0 && r.best_config.is_some()) {
            continue;
        }
        let fp_a = probe_cfg.fingerprint_of(&suite[i], rtx);
        for key in ["a100", "h100", "rtx4090"] {
            let fp_b = probe_cfg.fingerprint_of(&suite[i], gpu::by_key(key).unwrap());
            if router.route(fp_a, &alive) != router.route(fp_b, &alive) {
                found = Some((i, key));
                break 'outer;
            }
        }
    }
    let (anchor, other_gpu) = found.expect("some warm pair shards across the two nodes");

    // The second arrival lands far after the first flight's completion:
    // under dispatch-time causality a still-running flight can no longer
    // donate a warm seed (the old window-batched replay let it).
    let trace = vec![
        req_at(anchor, "rtx6000", Priority::Standard, 0, 0.0),
        req_at(anchor, other_gpu, Priority::Standard, 0, 100_000.0),
    ];
    let run = |transfer_latency_s: f64| {
        let mut svc = ClusterService::new(ClusterConfig {
            nodes: 2,
            transfer_latency_s,
            service: probe_cfg.clone(),
            ..ClusterConfig::default()
        });
        svc.replay(&trace, &suite, &NoOracle)
    };
    let free = run(0.0);
    assert_eq!(free.overall.flights_run, 2);
    assert_eq!(free.overall.warm_started, 1, "the second GPU's run seeds from the first");
    assert_eq!(free.cross_node_warm, 1, "the seed lives on the other shard");

    // The transfer is priced into the warm flight's service time: with two
    // served flights and everything else identical, the mean moves by
    // exactly transfer/2.
    let taxed = run(5000.0);
    assert_eq!(taxed.cross_node_warm, 1);
    let delta = taxed.overall.mean_latency_s - free.overall.mean_latency_s;
    assert!(
        (delta - 2500.0).abs() < 1e-6,
        "transfer latency must surface in the latency model, delta {delta}"
    );
}
