//! Property tests over the deficit-weighted-fair dispatcher (the
//! proptest role, via util::prop): random flight populations driven
//! straight through [`FleetSim`], checking the three contracts the
//! scheduler ships with — it is work-conserving (a free worker never
//! idles past an arrived flight), no tenant runs more than one
//! weight-normalized service ahead of its entitlement while a competitor
//! is backlogged, and the schedule is a pure function of the flight
//! *set*: permuting the submission order of same-instant arrivals (or
//! turning the fair pick off for a single tenant) replays bit for bit.

use std::collections::BTreeMap;

use cudaforge::service::fingerprint::Fingerprint;
use cudaforge::service::pool::{
    DispatchSnapshot, FleetHooks, FleetSim, MemberList, SimCompletion, SimFlight,
};
use cudaforge::service::queue::Priority;
use cudaforge::util::prop::{check_with, ensure, ensure_close};
use cudaforge::util::rng::Rng;

/// One scripted flight: everything needed to submit it and to predict
/// its service charge afterwards.
#[derive(Clone, Copy, Debug)]
struct Job {
    seq: u64,
    tenant: usize,
    arrival_s: f64,
    service_s: f64,
}

fn to_flight(j: &Job) -> SimFlight {
    SimFlight {
        // Distinct per seq, so single-flight dedup never merges jobs.
        fingerprint: Fingerprint(0x1000 + j.seq),
        priority: Priority::Standard,
        leader_seq: j.seq,
        tenant: j.tenant,
        arrival_s: j.arrival_s,
        members: MemberList::one(j.seq, j.arrival_s),
    }
}

/// Test hooks: fixed service time per leader seq; starts (with their
/// dispatch snapshots) and completions recorded in firing order.
struct Script {
    service: BTreeMap<u64, f64>,
    starts: Vec<(u64, f64, DispatchSnapshot)>,
    completions: Vec<(u64, SimCompletion)>,
}

impl Script {
    fn new(jobs: &[Job]) -> Script {
        Script {
            service: jobs.iter().map(|j| (j.seq, j.service_s)).collect(),
            starts: Vec::new(),
            completions: Vec::new(),
        }
    }
}

impl FleetHooks for Script {
    fn on_start(&mut self, f: &SimFlight, start_s: f64, fair: DispatchSnapshot) -> f64 {
        self.starts.push((f.leader_seq, start_s, fair));
        self.service[&f.leader_seq]
    }
    fn on_complete(&mut self, f: &SimFlight, done: SimCompletion) {
        self.completions.push((f.leader_seq, done));
    }
}

/// Submit every job to a fresh fleet and drain it.
fn run(jobs: &[Job], order: &[usize], workers: usize, fair: bool, weights: &[f64]) -> Script {
    let mut sim = FleetSim::new(workers);
    sim.set_fair_dispatch(fair);
    sim.set_tenant_weights(weights);
    let mut hooks = Script::new(jobs);
    for &i in order {
        sim.submit(to_flight(&jobs[i]));
    }
    sim.advance(f64::INFINITY, &mut hooks);
    assert_eq!(hooks.completions.len(), jobs.len(), "the fleet must drain");
    hooks
}

#[test]
fn prop_fair_dispatch_is_work_conserving() {
    // With one worker the work-conservation law is exact: every start
    // instant is max(worker frees, earliest arrival still waiting) — the
    // fair pick may reorder *which* flight runs, never *when* the worker
    // picks one up.
    check_with("dispatch-work-conserving", 0xD15B, 80, |rng| {
        let n = rng.range_usize(3, 24);
        let jobs: Vec<Job> = (0..n)
            .map(|i| Job {
                seq: i as u64,
                tenant: rng.below(3),
                arrival_s: rng.range_f64(0.0, 500.0),
                service_s: rng.range_f64(0.5, 60.0),
            })
            .collect();
        let order: Vec<usize> = (0..n).collect();
        let hooks = run(&jobs, &order, 1, true, &[1.0, 2.0, 0.5]);

        let mut remaining: Vec<bool> = vec![true; n];
        let mut free_at = 0.0f64;
        let mut total_service = 0.0f64;
        for (k, &(seq, start_s, _)) in hooks.starts.iter().enumerate() {
            let earliest = jobs
                .iter()
                .enumerate()
                .filter(|(i, _)| remaining[*i])
                .map(|(_, j)| j.arrival_s)
                .fold(f64::INFINITY, f64::min);
            ensure(
                start_s == free_at.max(earliest),
                format!(
                    "start #{k} at {start_s}, but the worker was free at {free_at} \
                     and the earliest waiting arrival was {earliest}"
                ),
            )?;
            let job = &jobs[seq as usize];
            ensure(job.arrival_s <= start_s, "a flight cannot start before it arrives")?;
            remaining[seq as usize] = false;
            free_at = start_s + job.service_s;
            total_service += job.service_s;
        }
        ensure(hooks.starts.len() == n, "every job starts exactly once")?;
        // Completions carry the same schedule the starts predict.
        for &(seq, done) in &hooks.completions {
            let svc = jobs[seq as usize].service_s;
            ensure_close(done.completion_s - done.start_s, svc, 1e-9, "service charged")?;
        }
        ensure(total_service > 0.0, "nonempty workload")?;
        Ok(())
    });
}

#[test]
fn prop_no_tenant_outruns_its_entitlement() {
    // Two tenants, both backlogged from t=0 on one worker: at every pick
    // the scheduler must take the tenant with the smaller normalized
    // deficit (ties to the lower index), the snapshot handed to the hooks
    // must equal the deficit recomputed from first principles, and the
    // deficit gap can never exceed one worst-case normalized service —
    // the discrete analogue of "never more than one quantum ahead".
    check_with("dispatch-entitlement-bound", 0xFA1, 80, |rng| {
        let weights = [
            *rng.choice(&[0.5, 1.0, 2.0, 3.0]),
            *rng.choice(&[0.5, 1.0, 2.0, 3.0]),
        ];
        let n0 = rng.range_usize(5, 12);
        let n1 = rng.range_usize(5, 12);
        let jobs: Vec<Job> = (0..n0 + n1)
            .map(|i| Job {
                seq: i as u64,
                tenant: usize::from(i >= n0),
                arrival_s: 0.0,
                service_s: rng.range_f64(1.0, 50.0),
            })
            .collect();
        let max_norm_service = jobs
            .iter()
            .map(|j| j.service_s / weights[j.tenant])
            .fold(0.0f64, f64::max);
        let order: Vec<usize> = (0..jobs.len()).collect();
        let hooks = run(&jobs, &order, 1, true, &weights);

        let mut deficit = [0.0f64; 2];
        let mut remaining = [n0, n1];
        for &(seq, _, fair) in &hooks.starts {
            let job = &jobs[seq as usize];
            let t = job.tenant;
            let other = 1 - t;
            ensure(
                fair.deficit_s == deficit[t],
                format!(
                    "snapshot deficit {} disagrees with recomputation {} for tenant {t}",
                    fair.deficit_s, deficit[t]
                ),
            )?;
            ensure(fair.weight == weights[t], "snapshot carries the configured weight")?;
            if remaining[other] > 0 {
                ensure(
                    (deficit[t], t) <= (deficit[other], other),
                    format!(
                        "picked tenant {t} at deficit {} over backlogged tenant \
                         {other} at deficit {}",
                        deficit[t], deficit[other]
                    ),
                )?;
            }
            deficit[t] += job.service_s / weights[t];
            remaining[t] -= 1;
            if remaining[0] > 0 && remaining[1] > 0 {
                ensure(
                    (deficit[0] - deficit[1]).abs() <= max_norm_service + 1e-9,
                    format!(
                        "deficit gap {} exceeds one normalized service {}",
                        (deficit[0] - deficit[1]).abs(),
                        max_norm_service
                    ),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_schedule_is_a_function_of_the_flight_set() {
    // Same-instant arrivals submitted in a permuted order — same seqs,
    // same flights, shuffled submission — must replay bit-identically:
    // the schedule depends on the flight *set*, not on host-side
    // iteration order. And with a single tenant, the fair pick must
    // degenerate to the historical strict order, bit for bit.
    check_with("dispatch-permutation-identity", 0x5EED, 80, |rng| {
        let n = rng.range_usize(2, 20);
        let workers = rng.range_usize(1, 3);
        let jobs: Vec<Job> = (0..n)
            .map(|i| Job {
                seq: i as u64,
                tenant: rng.below(3),
                arrival_s: 0.0,
                service_s: rng.range_f64(0.5, 40.0),
            })
            .collect();
        let weights = [1.0, 3.0, 0.5];
        let sorted: Vec<usize> = (0..n).collect();
        let mut shuffled = sorted.clone();
        rng.shuffle(&mut shuffled);

        let a = run(&jobs, &sorted, workers, true, &weights);
        let b = run(&jobs, &shuffled, workers, true, &weights);
        ensure(a.starts == b.starts, "starts must not depend on submission order")?;
        ensure(
            a.completions == b.completions,
            "completions must not depend on submission order",
        )?;

        // Single tenant: fair on == fair off, including the snapshots'
        // deficit bookkeeping (maintained either way for the traces).
        let solo: Vec<Job> = jobs.iter().map(|j| Job { tenant: 0, ..*j }).collect();
        let fair = run(&solo, &sorted, workers, true, &weights);
        let strict = run(&solo, &sorted, workers, false, &weights);
        ensure(fair.starts == strict.starts, "single tenant: fair == strict")?;
        ensure(fair.completions == strict.completions, "single tenant: fair == strict")?;
        Ok(())
    });
}
