//! Table 5: instantiate CudaForge with different base models for the Coder
//! and the Judge (fixing the other side to o3) — the framework is not tied
//! to a specific model.
//!
//!     cargo run --release --example model_matrix

use cudaforge::agents::profiles::{self, O3};
use cudaforge::coordinator::{default_threads, run_suite};
use cudaforge::gpu::RTX6000_ADA;
use cudaforge::tasks;
use cudaforge::workflow::{NoOracle, WorkflowConfig};

fn main() {
    let dstar = tasks::dstar();
    let combos = [
        ("O3 / O3", O3, O3),
        ("O3 / GPT-5", O3, profiles::GPT5),
        ("O3 / Claude-Sonnet-4", O3, profiles::CLAUDE_SONNET_4),
        ("O3 / GPT-OSS-120B", O3, profiles::GPT_OSS_120B),
        ("GPT-5 / O3", profiles::GPT5, O3),
        ("Claude-Sonnet-4 / O3", profiles::CLAUDE_SONNET_4, O3),
        ("GPT-OSS-120B / O3", profiles::GPT_OSS_120B, O3),
        ("QwQ / O3", profiles::QWQ_32B, O3),
    ];
    println!("== Table 5: base-model combinations (Coder/Judge) on D* ==\n");
    println!(
        "{:24} {:>8} {:>8} {:>8} {:>8} {:>8}",
        "Models (Coder/Judge)", "Correct", "Median", "75%", "Perf", "Fast1"
    );
    for (label, coder, judge) in combos {
        let mut wf = WorkflowConfig::cudaforge(&RTX6000_ADA, 2024);
        wf.coder = coder;
        wf.judge = judge;
        let out = run_suite(&wf, &dstar, &NoOracle, default_threads());
        let s = &out.overall;
        println!(
            "{:24} {:>7.1}% {:>8.3} {:>8.3} {:>8.3} {:>7.1}%",
            label,
            s.correct * 100.0,
            s.median,
            s.p75,
            s.perf,
            s.fast1 * 100.0
        );
    }
    println!("\nexpected shape (paper): every combo strong; judge-side GPT-5 peaks Perf;");
    println!("QwQ as Coder is the weakest (84% correct, 0.79x in the paper).");
}
