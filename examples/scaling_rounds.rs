//! Figure 7: test-time scaling — raise the maximum iteration rounds N from
//! 1 to 30 and watch the speedup climb steeply to N=10, then saturate
//! (the paper reaches 2.27x at N=30 on D*).
//!
//!     cargo run --release --example scaling_rounds

use cudaforge::coordinator::{default_threads, run_suite};
use cudaforge::gpu::RTX6000_ADA;
use cudaforge::tasks;
use cudaforge::workflow::{NoOracle, WorkflowConfig};

fn main() {
    let dstar = tasks::dstar();
    println!("== Figure 7: scaling max rounds N on D* ==\n");
    println!("{:>4} {:>9} {:>9} {:>9} {:>8}  bar", "N", "Correct", "Median", "Perf", "Fast1");
    let mut prev = 0.0;
    for n in [1usize, 2, 4, 6, 8, 10, 15, 20, 25, 30] {
        let wf = WorkflowConfig::cudaforge(&RTX6000_ADA, 2024).with_rounds(n);
        let out = run_suite(&wf, &dstar, &NoOracle, default_threads());
        let s = &out.overall;
        let bar = "#".repeat((s.perf * 20.0) as usize);
        println!(
            "{n:>4} {:>8.1}% {:>9.3} {:>9.3} {:>7.1}%  {bar}",
            s.correct * 100.0,
            s.median,
            s.perf,
            s.fast1 * 100.0
        );
        assert!(
            s.perf >= prev - 0.25,
            "scaling curve should not collapse: N={n} perf {} after {prev}",
            s.perf
        );
        prev = s.perf;
    }
    println!("\nexpected shape: steep gains 1->10, diminishing 10->30 (paper: 2.27x at 30).");
}
