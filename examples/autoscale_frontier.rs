//! Autoscaling walkthrough: replay one Zipf trace through every
//! (policy, scenario) combination and print the cost/SLO frontier — the
//! operational question the autoscaling subsystem exists for: how many
//! node-hours does each policy spend, and what does that spend buy in
//! per-priority SLO attainment, tail latency, and shed counts, once the
//! policy's own churn (cache-entry losses, transfer gaps, re-run bills) is
//! priced by the rebalance machinery?
//!
//! The fleet has 6 node slots of which 4 start alive, so policies have
//! headroom in both directions; joins pay a 10-minute provisioning delay,
//! fails land immediately. The static policy is the do-nothing baseline —
//! under the steady scenario it reproduces the plain `cluster` replay bit
//! for bit.
//!
//!     cargo run --release --example autoscale_frontier

use cudaforge::cluster::autoscale::{policy_by_name, AutoscaleConfig, POLICY_NAMES};
use cudaforge::cluster::{AutoscaleRun, ClusterConfig, ClusterService, Scenario};
use cudaforge::report::{frontier_table, FrontierRow};
use cudaforge::service::traffic::{generate, TrafficConfig};
use cudaforge::service::ServiceConfig;
use cudaforge::tasks;
use cudaforge::workflow::NoOracle;

const SLOTS: usize = 6;
const START_ALIVE: usize = 4;

fn main() {
    let suite = tasks::kernelbench();
    let base_trace = generate(
        suite.len(),
        &TrafficConfig { requests: 800, ..TrafficConfig::default() },
    );

    let mut rows = Vec::new();
    for scenario in Scenario::all() {
        // The shapers move arrival instants only — same tasks, same GPUs,
        // same tenants — so every policy faces the same work, differently
        // timed.
        let mut trace = base_trace.clone();
        scenario.shape_arrivals(&mut trace);
        let span_s = trace.last().map(|r| r.arrival_s).unwrap_or(0.0);

        for policy_name in POLICY_NAMES {
            let policy = policy_by_name(policy_name).expect("known policy");
            let mut run = AutoscaleRun::new(
                policy,
                AutoscaleConfig {
                    tick_s: 3600.0,
                    provision_delay_s: 600.0,
                    min_nodes: 1,
                    max_nodes: SLOTS,
                },
            );
            let mut config = ClusterConfig {
                nodes: SLOTS,
                initial_dead: (START_ALIVE..SLOTS).collect(),
                node_service_multipliers: scenario.service_multipliers(SLOTS),
                service: ServiceConfig { window: 32, ..ServiceConfig::default() },
                ..ClusterConfig::default()
            };
            config.events.extend(scenario.membership_events(START_ALIVE, span_s));

            let mut svc = ClusterService::new(config);
            let report = svc.replay_autoscaled(&trace, &suite, &NoOracle, &mut run);
            println!(
                "{:>17} x {:<16} {:>2} ticks  {:>2} joins  {:>2} fails  \
                 {:>8.2} node-hrs  {:>4} shed",
                scenario.name(),
                policy_name,
                run.ticks,
                run.joins(),
                run.fails(),
                report.node_hours,
                report.overall.rejected,
            );
            rows.push(FrontierRow {
                policy: policy_name.to_string(),
                scenario: scenario.name().to_string(),
                joins: run.joins(),
                fails: run.fails(),
                report,
            });
        }
    }

    println!("{}", frontier_table(&rows).render());
}
