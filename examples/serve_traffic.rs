//! Service-layer walkthrough: replay Zipf traffic through the kernel-
//! optimization service, snapshot the cache, then restart warm and replay a
//! second day of traffic to show the economics of a persistent cache.
//!
//!     cargo run --release --example serve_traffic

use cudaforge::report::service_table;
use cudaforge::service::cache::ResultCache;
use cudaforge::service::traffic::{generate, TrafficConfig};
use cudaforge::service::{KernelService, ServiceConfig};
use cudaforge::tasks;
use cudaforge::workflow::NoOracle;

fn main() {
    let suite = tasks::kernelbench();
    let config = ServiceConfig { window: 32, ..ServiceConfig::default() };
    let snapshot = std::env::temp_dir().join("cudaforge_serve_traffic.jsonl");

    // ---- day 1: cold service ----------------------------------------------
    let day1 = generate(
        suite.len(),
        &TrafficConfig { requests: 800, seed: 7, ..TrafficConfig::default() },
    );
    let mut svc = KernelService::new(config.clone());
    let r1 = svc.replay(&day1, &suite, &NoOracle);
    println!("{}", service_table(&r1).render());
    println!(
        "day 1 (cold start): hit rate {:.1}%, ${:.2} spent, ${:.2} saved\n",
        r1.hit_rate * 100.0,
        r1.api_usd_spent,
        r1.api_usd_saved
    );
    svc.cache().snapshot(&snapshot).expect("snapshot");
    println!("[cache snapshot: {} entries -> {}]\n", svc.cache().len(), snapshot.display());

    // ---- day 2: restart warm from the snapshot ----------------------------
    let cache = ResultCache::restore(&snapshot, config.capacity).expect("restore");
    let mut warm_svc = KernelService::with_cache(config, cache);
    let day2 = generate(
        suite.len(),
        &TrafficConfig { requests: 800, seed: 8, ..TrafficConfig::default() },
    );
    let r2 = warm_svc.replay(&day2, &suite, &NoOracle);
    println!("{}", service_table(&r2).render());
    println!(
        "day 2 (warm restart, new traffic mix): hit rate {:.1}% vs day-1 {:.1}%, \
         ${:.2} spent vs ${:.2}",
        r2.hit_rate * 100.0,
        r1.hit_rate * 100.0,
        r2.api_usd_spent,
        r1.api_usd_spent
    );
    println!(
        "warm-started runs reached their best kernel in {:.2} mean rounds (cold: {:.2})",
        r2.mean_rounds_to_best_warm, r2.mean_rounds_to_best_cold
    );
}
