//! Service-layer walkthrough: replay Zipf traffic through the kernel-
//! optimization service, snapshot the cache, restart warm and replay a
//! second day of traffic, then sweep the simulated GPU fleet size to answer
//! the capacity-planning question: how many GPUs does this traffic need to
//! meet its per-priority SLOs? The replay is event-driven — cache refills
//! and warm-start eligibility land at each flight's simulated completion
//! instant, and the `window` knob only batches host-side OS-thread work —
//! which the last section demonstrates by replaying the same trace under
//! two very different window sizes and comparing the reports bit for bit.
//!
//!     cargo run --release --example serve_traffic

use cudaforge::report::service_table;
use cudaforge::service::cache::ResultCache;
use cudaforge::service::traffic::{generate, TrafficConfig};
use cudaforge::service::{KernelService, ServiceConfig};
use cudaforge::tasks;
use cudaforge::workflow::NoOracle;

fn main() {
    let suite = tasks::kernelbench();
    let config = ServiceConfig { window: 32, ..ServiceConfig::default() };
    let snapshot = std::env::temp_dir().join("cudaforge_serve_traffic.jsonl");

    // ---- day 1: cold service ----------------------------------------------
    let day1 = generate(
        suite.len(),
        &TrafficConfig { requests: 800, seed: 7, ..TrafficConfig::default() },
    );
    let mut svc = KernelService::new(config.clone());
    let r1 = svc.replay(&day1, &suite, &NoOracle);
    println!("{}", service_table(&r1).render());
    println!(
        "day 1 (cold start): hit rate {:.1}%, ${:.2} spent, ${:.2} saved, \
         mean queue wait {:.1} min on {} simulated GPUs\n",
        r1.hit_rate * 100.0,
        r1.api_usd_spent,
        r1.api_usd_saved,
        r1.mean_queue_wait_s / 60.0,
        config.sim_workers,
    );
    svc.cache().snapshot(&snapshot).expect("snapshot");
    println!("[cache snapshot: {} entries -> {}]\n", svc.cache().len(), snapshot.display());

    // ---- day 2: restart warm from the snapshot ----------------------------
    let cache = ResultCache::restore(&snapshot, config.capacity).expect("restore");
    let mut warm_svc = KernelService::with_cache(config.clone(), cache);
    let day2 = generate(
        suite.len(),
        &TrafficConfig { requests: 800, seed: 8, ..TrafficConfig::default() },
    );
    let r2 = warm_svc.replay(&day2, &suite, &NoOracle);
    println!("{}", service_table(&r2).render());
    println!(
        "day 2 (warm restart, new traffic mix): hit rate {:.1}% vs day-1 {:.1}%, \
         ${:.2} spent vs ${:.2}",
        r2.hit_rate * 100.0,
        r1.hit_rate * 100.0,
        r2.api_usd_spent,
        r1.api_usd_spent
    );
    println!(
        "warm-started runs reached their best kernel in {:.2} mean rounds (cold: {:.2})\n",
        r2.mean_rounds_to_best_warm, r2.mean_rounds_to_best_cold
    );

    // ---- capacity planning: sweep the simulated fleet ---------------------
    println!("fleet sizing on day-1 traffic (cold cache each run):");
    println!(
        "{:>8}  {:>9}  {:>9}  {:>10}  {:>12}  {:>12}",
        "GPUs", "p95 (m)", "p99 (m)", "wait (m)", "util", "batch SLO"
    );
    for sim_workers in [1usize, 2, 4, 8, 16] {
        let mut s = KernelService::new(ServiceConfig { sim_workers, ..config.clone() });
        let r = s.replay(&day1, &suite, &NoOracle);
        let batch = r
            .per_priority
            .iter()
            .find(|c| c.priority.name() == "batch")
            .expect("batch class present");
        println!(
            "{:>8}  {:>9.1}  {:>9.1}  {:>10.1}  {:>11.1}%  {:>11.1}%",
            sim_workers,
            r.p95_latency_s / 60.0,
            r.p99_latency_s / 60.0,
            r.mean_queue_wait_s / 60.0,
            r.utilization * 100.0,
            batch.slo_attainment * 100.0,
        );
    }

    // ---- the window knob is host-side only --------------------------------
    // Dispatch is event-driven, so the speculative batch size changes how
    // the host crunches runs, never what the simulation reports.
    let mut narrow = KernelService::new(ServiceConfig { window: 1, ..config.clone() });
    let mut wide = KernelService::new(ServiceConfig { window: 128, ..config.clone() });
    let rn = narrow.replay(&day1, &suite, &NoOracle);
    let rw = wide.replay(&day1, &suite, &NoOracle);
    println!(
        "\nwindow 1 vs 128 on day-1 traffic: reports bit-identical? {}",
        if rn == rw { "yes" } else { "NO (bug!)" }
    );
}
