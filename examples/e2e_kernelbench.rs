//! End-to-end driver (DESIGN.md "End-to-end validation"): proves all three
//! layers compose on a real workload.
//!
//! 1. Loads every AOT artifact (Pallas L1 kernels lowered through JAX L2)
//!    and executes each variant against its pure-jnp reference on the PJRT
//!    CPU client, reporting per-artifact verdicts and latencies.
//! 2. Runs the full CudaForge workflow over the paper's stratified subset D*
//!    (25 tasks) with the real-numerics oracle driving the correctness stage
//!    on every artifact-bound anchor.
//! 3. Reports the paper's headline metrics (correctness %, mean/median
//!    speedup, Fast_1, $/kernel, min/kernel).
//!
//!     make artifacts && cargo run --release --example e2e_kernelbench

#[cfg(not(feature = "pjrt"))]
fn main() {
    println!(
        "e2e_kernelbench needs the PJRT engine — rebuild with `--features pjrt` \
         (requires the vendored `xla` crate, see rust/Cargo.toml)"
    );
}

#[cfg(feature = "pjrt")]
fn main() {
    use std::time::Instant;

    use cudaforge::coordinator::{default_threads, run_suite};
    use cudaforge::gpu::RTX6000_ADA;
    use cudaforge::runtime::oracle::{RealOracle, VerificationMatrix};
    use cudaforge::runtime::Engine;
    use cudaforge::tasks;
    use cudaforge::workflow::WorkflowConfig;

    // ---- stage 1: execute every artifact on PJRT --------------------------
    let mut engine = Engine::new("artifacts")
        .expect("artifacts/manifest.json missing — run `make artifacts` first");
    println!("== stage 1: PJRT execution of all kernel artifacts ==");
    let t0 = Instant::now();
    let names: Vec<String> = engine
        .manifest()
        .entries
        .iter()
        .filter(|e| !e.reference.is_empty())
        .map(|e| e.name.clone())
        .collect();
    let mut pass = 0;
    let mut fail = 0;
    for name in &names {
        let t1 = Instant::now();
        let (ok, max_diff, n) = engine.check_against_ref(name, 42).expect(name);
        let label_ok = ok == !name.contains("bug_");
        println!(
            "  {:36} {:8} max|diff|={:.3e} ({} elems, {:5.1} ms) {}",
            name,
            if ok { "PASS" } else { "MISMATCH" },
            max_diff,
            n,
            t1.elapsed().as_secs_f64() * 1e3,
            if label_ok { "" } else { "<-- INCONSISTENT" },
        );
        if label_ok { pass += 1 } else { fail += 1 }
    }
    println!(
        "stage 1: {}/{} artifacts consistent with their labels in {:.1}s\n",
        pass,
        pass + fail,
        t0.elapsed().as_secs_f64()
    );
    assert_eq!(fail, 0, "artifact verdicts inconsistent");

    // ---- stage 2: CudaForge over D* with the real oracle -------------------
    println!("== stage 2: CudaForge over D* (25 tasks) with real-numerics oracle ==");
    let matrix = VerificationMatrix::build(&mut engine, 42).expect("matrix");
    let oracle = RealOracle::new(matrix);
    let dstar = tasks::dstar();
    let wf = WorkflowConfig::cudaforge(&RTX6000_ADA, 2024);
    let t2 = Instant::now();
    let out = run_suite(&wf, &dstar, &oracle, default_threads());
    let bound: u32 = out.results.iter().map(|r| r.oracle_checks).sum();
    for r in &out.results {
        println!(
            "  {:7} best={:7.3}x correct={:5} rounds={} real-checks={}",
            r.task_id,
            r.best_speedup,
            r.correct,
            r.rounds.len(),
            r.oracle_checks
        );
    }

    // ---- stage 3: headline metrics ----------------------------------------
    let s = &out.overall;
    println!("\n== stage 3: headline metrics (paper Table 1, CudaForge* row) ==");
    println!("  tasks:            {}", s.n_tasks);
    println!("  correctness:      {:.1}%   (paper: 100% on D*)", s.correct * 100.0);
    println!("  mean speedup:     {:.3}x  (paper: 1.767x)", s.perf);
    println!("  median speedup:   {:.3}x  (paper: 1.322x)", s.median);
    println!("  75th percentile:  {:.3}x  (paper: 1.736x)", s.p75);
    println!("  Fast_1:           {:.1}%   (paper: 84.0%)", s.fast1 * 100.0);
    println!("  modelled cost:    ${:.2} / kernel (paper: $0.30)", s.avg_cost_usd);
    println!("  modelled time:    {:.1} min / kernel (paper: 26.5)", s.avg_time_min);
    println!("  real PJRT checks: {bound} across the suite");
    println!("  harness wall:     {:.1}s", t2.elapsed().as_secs_f64());
}
