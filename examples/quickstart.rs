//! Quickstart: optimize one KernelBench task with the full CudaForge loop
//! and print each round's Judge verdict and measured speedup.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the real-numerics PJRT oracle when `artifacts/` exists (run
//! `make artifacts` first), otherwise the modelled correctness check.

use cudaforge::gpu::RTX6000_ADA;
use cudaforge::runtime;
use cudaforge::tasks;
use cudaforge::workflow::{run_task, CorrectnessOracle, NoOracle, WorkflowConfig};

fn main() {
    let task = tasks::by_id("L2-51").expect("the Appendix-B.1 anchor task");
    println!("task: {} — {} (level {})", task.id(), task.name, task.level);

    // Real numerics when the AOT artifacts are present (pjrt feature).
    let oracle: Box<dyn CorrectnessOracle> = match runtime::try_real_oracle("artifacts", 42) {
        Some(o) => {
            println!(
                "real-numerics oracle: {} artifacts verified on PJRT\n",
                o.matrix().verdicts.len()
            );
            Box::new(o)
        }
        None => {
            println!("(no PJRT oracle; modelled correctness — run `make artifacts` + --features pjrt)\n");
            Box::new(NoOracle)
        }
    };

    let wf = WorkflowConfig::cudaforge(&RTX6000_ADA, 7);
    let result = run_task(&wf, &task, oracle.as_ref());

    for r in &result.rounds {
        println!(
            "round {:>2} [{:12}] correct={:5} speedup={}",
            r.round,
            r.mode,
            r.correct,
            r.speedup.map(|s| format!("{s:.3}x")).unwrap_or_else(|| "   -  ".into()),
        );
        if !r.feedback_json.is_empty() {
            println!("   judge -> {}", r.feedback_json);
        }
    }
    println!(
        "\nbest speedup {:.3}x over the PyTorch reference | ${:.2} API | {:.1} min wall",
        result.best_speedup,
        result.ledger.api_usd,
        result.ledger.wall_min()
    );
    if let Some(cfg) = &result.best_config {
        println!("final kernel configuration:\n  {}", cfg.describe());
    }
}
