//! Table 4: CudaForge generalization across GPU architectures.
//!
//! Runs the identical workflow on D* for RTX 6000 Ada / RTX 4090 / A100 /
//! RTX 3090 (+ H200 as a bonus) — the hardware feedback (GPU specs + NCU
//! metrics) is what adapts the kernels per target, with zero retraining.
//!
//!     cargo run --release --example gpu_sweep

use cudaforge::coordinator::{default_threads, run_suite};
use cudaforge::gpu;
use cudaforge::tasks;
use cudaforge::workflow::{NoOracle, WorkflowConfig};

fn main() {
    let dstar = tasks::dstar();
    println!("== Table 4: CudaForge across GPUs (D*, N=10, o3/o3) ==\n");
    println!(
        "{:38} {:>8} {:>8} {:>8} {:>8} {:>8}",
        "GPU", "Correct", "Median", "75%", "Perf", "Fast1"
    );
    for key in ["rtx6000", "rtx4090", "a100", "rtx3090", "h200"] {
        let g = gpu::by_key(key).unwrap();
        let wf = WorkflowConfig::cudaforge(g, 2024);
        let out = run_suite(&wf, &dstar, &NoOracle, default_threads());
        let s = &out.overall;
        println!(
            "{:38} {:>7.1}% {:>8.3} {:>8.3} {:>8.3} {:>7.1}%",
            format!("{} ({})", g.name, g.arch.name()),
            s.correct * 100.0,
            s.median,
            s.p75,
            s.perf,
            s.fast1 * 100.0
        );
    }
    println!("\npaper (Table 4): RTX6000 1.767x | 4090 1.327x | A100 1.841x | 3090 1.320x");
    println!("expected shape: data-center parts lead desktop parts within an arch family.");
}
