//! Figure 8 case study: KernelBench Level-1 task 95 (CrossEntropyLoss).
//!
//! Replays the paper's 10-round narrative: barrier-stall diagnosis leading to
//! a warp-shuffle reduction, a mid-run correction round for an uninitialized
//! target_logit, and long-scoreboard-driven register/caching optimizations —
//! printing the Judge's JSON verdicts and the per-round speedups.
//!
//!     cargo run --release --example case_study

use cudaforge::gpu::RTX6000_ADA;
use cudaforge::runtime;
use cudaforge::tasks;
use cudaforge::util::json::Json;
use cudaforge::workflow::{run_task, CorrectnessOracle, NoOracle, WorkflowConfig};

fn main() {
    let task = tasks::by_id("L1-95").unwrap();
    println!("== Figure 8 case study: {} ({}) ==\n", task.id(), task.name);

    let oracle: Box<dyn CorrectnessOracle> = match runtime::try_real_oracle("artifacts", 42) {
        Some(o) => Box::new(o),
        None => Box::new(NoOracle),
    };

    // Try several seeds and present the run that contains at least one
    // correction round — the paper's Figure 8 shows a 10-round trace with
    // three optimization rounds and one repair round.
    let mut chosen = None;
    for seed in 0..400u64 {
        let wf = WorkflowConfig::cudaforge(&RTX6000_ADA, seed);
        let r = run_task(&wf, &task, oracle.as_ref());
        let has_repair = r.rounds.iter().any(|x| x.mode == "correction");
        let opt_suggestions = r
            .rounds
            .iter()
            .filter(|x| x.feedback_json.contains("\"bottleneck\""))
            .count();
        if has_repair && opt_suggestions >= 3 && r.correct && r.best_speedup > 1.2 {
            chosen = Some((seed, r));
            break;
        }
    }
    let (seed, r) = chosen.expect("a qualifying trace exists");
    println!("(seed {seed}; green = optimization, red = correction)\n");
    for round in &r.rounds {
        let marker = match round.mode {
            "correction" => "[RED  ]",
            "optimization" => "[GREEN]",
            _ => "[INIT ]",
        };
        println!(
            "{marker} round {:>2}: correct={:5} speedup={}",
            round.round,
            round.correct,
            round.speedup.map(|s| format!("{s:.3}x")).unwrap_or_else(|| "-".into())
        );
        if !round.feedback_json.is_empty() {
            let v = Json::parse(&round.feedback_json).unwrap();
            if let Some(b) = v.get("bottleneck").and_then(|x| x.as_str()) {
                println!("         judge bottleneck : {b}");
                if let Some(m) = v.get("optimisation method").and_then(|x| x.as_str()) {
                    println!("         judge suggestion : {m}");
                }
            } else if let Some(issue) = v.get("critical_issue").and_then(|x| x.as_str()) {
                println!("         judge diagnosis  : {issue}");
                if let Some(h) = v.get("minimal_fix_hint").and_then(|x| x.as_str()) {
                    println!("         judge fix hint   : {h}");
                }
            }
        }
    }
    println!("\nfinal: best speedup {:.3}x over PyTorch", r.best_speedup);
    if let Some(cfg) = &r.best_config {
        println!("kernel: {}", cfg.describe());
    }
}
