//! Cluster-layer walkthrough: replay Zipf traffic from two tenants over a
//! sharded 4-node cluster, then answer the operational questions the
//! simulation exists for — what does a node failure cost, what does
//! *recovering* the node cost (the planned rebalance that warm-refills its
//! keys), can a warm cluster survive a restart through a shard-aware
//! snapshot, and do fair-share quotas actually protect the light tenant
//! when a heavy tenant floods the queue? All node fleets advance through
//! one global event loop, so a cross-node warm start only ever seeds from
//! an entry whose producing flight has already completed — and a rebalance
//! refill is only visible once its transfer has landed — in simulated time.
//!
//!     cargo run --release --example cluster_sim

use cudaforge::cluster::{ClusterConfig, ClusterService, MembershipEvent, TenantSpec};
use cudaforge::report::cluster_table;
use cudaforge::service::traffic::{generate, TrafficConfig};
use cudaforge::service::ServiceConfig;
use cudaforge::tasks;
use cudaforge::workflow::NoOracle;

fn base_config() -> ClusterConfig {
    ClusterConfig {
        nodes: 4,
        tenants: vec![TenantSpec::new("alpha", 3.0), TenantSpec::new("beta", 1.0)],
        tenant_quotas: true,
        transfer_latency_s: 30.0,
        warm_locality_margin: 0.25,
        service: ServiceConfig {
            window: 32,
            sim_workers: 2,
            capacity: 512,
            queue_depth: 16,
            ..ServiceConfig::default()
        },
        events: Vec::new(),
        ..ClusterConfig::default()
    }
}

fn main() {
    let suite = tasks::kernelbench();
    let traffic = TrafficConfig {
        requests: 1200,
        seed: 7,
        tenant_mix: vec![("alpha".to_string(), 3.0), ("beta".to_string(), 1.0)],
        ..TrafficConfig::default()
    };
    let trace = generate(suite.len(), &traffic);

    // ---- healthy cluster --------------------------------------------------
    let mut svc = ClusterService::new(base_config());
    let healthy = svc.replay(&trace, &suite, &NoOracle);
    println!("{}", cluster_table(&healthy).render());
    println!(
        "healthy: hit rate {:.1}% over {} nodes, {} cross-node warm starts, \
         {} quota sheds\n",
        healthy.overall.hit_rate * 100.0,
        healthy.nodes,
        healthy.cross_node_warm,
        healthy.quota_shed,
    );

    // ---- node failure + planned recovery mid-replay -----------------------
    // Drop node 1 a third of the way into the trace: its shard is lost, its
    // keys rehash to survivors, and every lost key that comes back re-runs
    // a workflow the cluster had already paid for. Two thirds in, the node
    // *rejoins* empty: the inverse movement is a planned rebalance — every
    // surviving-shard entry the newcomer owns is warm-refilled to it,
    // priced like a cross-node transfer instead of a re-run.
    let fail_at = trace[trace.len() / 3].arrival_s;
    let rejoin_at = trace[2 * trace.len() / 3].arrival_s;
    let mut degraded_cfg = base_config();
    degraded_cfg.events =
        vec![MembershipEvent::fail(1, fail_at), MembershipEvent::join(1, rejoin_at)];
    let mut degraded_svc = ClusterService::new(degraded_cfg);
    let degraded = degraded_svc.replay(&trace, &suite, &NoOracle);
    for rb in &degraded.rebalances {
        match rb.kind {
            cudaforge::cluster::RebalanceKind::NodeFailure => println!(
                "failure: node {} dropped at t={:.0}s — {} cached entries lost, {} \
                 requests rehashed, {} lost keys re-ran cold (${:.2} re-spent)",
                rb.node,
                rb.at_s,
                rb.cache_entries_lost,
                rb.rehashed_requests,
                rb.remissed_flights,
                rb.remiss_api_usd,
            ),
            cudaforge::cluster::RebalanceKind::NodeJoin => println!(
                "recovery: node {} rejoined at t={:.0}s — {} entries warm-refilled \
                 ({:.0}s transfer spend), {} keys re-ran inside the gap (${:.2})",
                rb.node,
                rb.at_s,
                rb.entries_moved,
                rb.transfer_s,
                rb.remissed_flights,
                rb.remiss_api_usd,
            ),
            cudaforge::cluster::RebalanceKind::SnapshotRestore => {}
        }
    }
    println!(
        "failure tax on spend: ${:.2} (degraded) vs ${:.2} (healthy); membership \
         epoch ended at {}\n",
        degraded.overall.api_usd_spent, healthy.overall.api_usd_spent, degraded.epoch,
    );

    // ---- shard-aware snapshot: a warm restart survives ---------------------
    // Persist the healthy cluster, restore it, and replay fresh traffic
    // through both: the restored cluster serves it bit-identically — the
    // manifest carries the epoch and per-shard files, recency and the
    // cold-cost registry included.
    let snap_dir = std::env::temp_dir().join("cudaforge_cluster_sim_snapshot");
    let _ = std::fs::remove_dir_all(&snap_dir);
    let manifest = svc.snapshot(&snap_dir).expect("snapshot");
    let (mut restored, moved) =
        ClusterService::restore(base_config(), &snap_dir).expect("restore");
    let day2 = generate(
        suite.len(),
        &TrafficConfig {
            requests: 600,
            seed: 13,
            tenant_mix: vec![("alpha".to_string(), 3.0), ("beta".to_string(), 1.0)],
            ..TrafficConfig::default()
        },
    );
    let warm_day2 = restored.replay(&day2, &suite, &NoOracle);
    let same_day2 = svc.replay(&day2, &suite, &NoOracle);
    println!(
        "snapshot: {} entries across {} shards (epoch {}); restored replay \
         bit-identical to the warm original: {} (hit rate {:.1}%, moved on \
         restore: {})\n",
        manifest.shards.iter().map(|s| s.entries).sum::<usize>(),
        manifest.nodes,
        manifest.epoch,
        warm_day2 == same_day2,
        warm_day2.overall.hit_rate * 100.0,
        moved.map(|m| m.entries_moved).unwrap_or(0),
    );

    // ---- tenant overload: quotas on vs off --------------------------------
    // A flood: alpha turns abusive (interactive-heavy, dense arrivals). With
    // quotas the light tenant keeps its fair share of every node's backlog;
    // without them it queues behind the flood.
    let flood = TrafficConfig {
        requests: 1500,
        seed: 11,
        mean_interarrival_s: 10.0,
        tenant_mix: vec![("alpha".to_string(), 9.0), ("beta".to_string(), 1.0)],
        priority_mix: [0.5, 0.5, 0.0],
        ..TrafficConfig::default()
    };
    let flood_trace = generate(suite.len(), &flood);
    println!("overload (alpha floods 9:1, no batch class to shed):");
    println!(
        "{:>9}  {:>12}  {:>12}  {:>12}  {:>12}",
        "quotas", "alpha SLO", "beta SLO", "alpha shed", "beta shed"
    );
    for quotas in [true, false] {
        let mut cfg = base_config();
        cfg.tenant_quotas = quotas;
        let mut s = ClusterService::new(cfg);
        let r = s.replay(&flood_trace, &suite, &NoOracle);
        let alpha = &r.per_tenant[0];
        let beta = &r.per_tenant[1];
        println!(
            "{:>9}  {:>11.1}%  {:>11.1}%  {:>12}  {:>12}",
            if quotas { "on" } else { "off" },
            alpha.slo_attainment * 100.0,
            beta.slo_attainment * 100.0,
            alpha.rejected,
            beta.rejected,
        );
    }
}
